"""Multi-host sharded serving (DESIGN.md §6): scorer wire format, quorum
vote + two-phase swap protocol, merged-reservoir estimator equivalence,
and K=4 end-to-end conservation across a quorum-voted plan swap."""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # benchmarks/

from repro.core import optimize
from repro.data.synthetic import (
    make_dataset,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.distributed.consensus import (
    DriftVote,
    QuorumSwapCoordinator,
    SwapAck,
    quorum,
)
from repro.distributed.serving import ShardedCascadeServer, ShardHost
from repro.kernels.ops import (
    WireFormatError,
    cascade_scorer_for_plan,
    deserialize_scorer,
    serialize_scorer,
)
from repro.serving.stats import (
    AdaptivePolicy,
    DriftEvent,
    Reservoir,
    ReservoirSample,
    ipw_selectivity,
    merge_reservoir_samples,
)


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=9000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=41)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=41,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=42)
    return ds, q


@pytest.fixture(scope="module")
def mixed_plan(workload):
    ds, q = workload
    return optimize(q, ds.x[:1200], mode="core-a", step=0.05, kind="mixed")


def _policy(**kw):
    base = dict(cooldown_records=1024, min_reservoir=128, threshold=50.0,
                audit_rate=0.03, reservoir_capacity=512)
    base.update(kw)
    return AdaptivePolicy(**base)


# ------------------------------------------------------------- wire format
def test_wire_roundtrip_bit_exact(workload, mixed_plan):
    """serialize -> deserialize -> serialize reproduces the exact bytes;
    the deserialized scorer's packed tensors, thresholds, and keep masks
    are bit-identical to the sender's (mixed linear+MLP cascade)."""
    ds, q = workload
    scorer, _ = cascade_scorer_for_plan(mixed_plan)
    blob = serialize_scorer(mixed_plan, scorer)
    plan2, scorer2 = deserialize_scorer(blob, q)
    assert serialize_scorer(plan2, scorer2) == blob
    for a, b in [(scorer.packed.w1, scorer2.packed.w1),
                 (scorer.packed.b1, scorer2.packed.b1),
                 (scorer.packed.w2, scorer2.packed.w2),
                 (scorer.packed.b2, scorer2.packed.b2)]:
        assert a.dtype == b.dtype and np.array_equal(a, b)
    assert np.array_equal(np.asarray(scorer.thr), np.asarray(scorer2.thr))
    x = ds.x[2000:3000]
    assert np.array_equal(scorer.score_masks(x), scorer2.score_masks(x))
    # plan metadata survives: order, thresholds, estimates, r-curves
    assert plan2.order == mixed_plan.order
    for s1, s2 in zip(mixed_plan.stages, plan2.stages):
        assert s2.threshold == float(s1.threshold)
        assert s2.alpha == float(s1.alpha)
        assert np.array_equal(s1.proxy.r_curve.thresholds,
                              s2.proxy.r_curve.thresholds)
    # deserialized proxies are first-class packed1 models: reference
    # scoring still works and agrees with the original family's scorer
    s_ref = plan2.stages[0].proxy.score(x[:64])
    s_orig = mixed_plan.stages[0].proxy.score(x[:64])
    assert np.allclose(s_ref, s_orig, atol=1e-5)


def test_wire_rejects_garbage_and_mismatches(workload, mixed_plan):
    ds, q = workload
    blob = serialize_scorer(mixed_plan)
    with pytest.raises(WireFormatError):
        deserialize_scorer(b"NOTAWIRE" + blob[8:], q)
    bad_ver = blob[:8] + (99).to_bytes(2, "little") + blob[10:]
    with pytest.raises(WireFormatError):
        deserialize_scorer(bad_ver, q)
    # wrong query shape: a 2-predicate query cannot bind a 3-stage artifact
    udfs2 = [q.predicates[0].udf, q.predicates[1].udf]
    from repro.core.query import Predicate, Query

    q2 = Query([Predicate(udf=u, values=frozenset({1})) for u in udfs2],
               accuracy_target=0.9)
    with pytest.raises(WireFormatError):
        deserialize_scorer(blob, q2)


def test_packed1_family_is_not_trainable(workload, mixed_plan):
    ds, q = workload
    plan2, _ = deserialize_scorer(serialize_scorer(mixed_plan), q)
    from repro.core.proxy_family import get_family

    with pytest.raises(TypeError):
        get_family("packed1").train(ds.x[:32], np.ones(32), 0)


# --------------------------------------------- scorer cache vs id reuse
def test_scorer_cache_immune_to_param_id_reuse(workload):
    """Regression (ISSUE 4 sweep): the compile cache used to key on
    ``id(params)``; recycled ids (params GC'd, new allocation at the same
    address) could then alias a stale compiled scorer.  Content
    fingerprints make the hazard structurally impossible — this test
    provokes real id reuse and checks every lookup still scores with the
    CURRENT parameters."""
    import gc

    from repro.core.proxy import ProxyModel, build_r_curve
    from repro.core.query import PhysicalPlan, PlanStage
    from repro.kernels import ops
    from repro.training.proxy_models import LinearParams

    ds, q = workload
    x = ds.x[:256].astype(np.float32)
    F = x.shape[1]
    rng = np.random.RandomState(0)

    def fresh_plan(seed):
        w = rng.randn(F).astype(np.float32)
        params = LinearParams(w=w, b=np.float32(0.1 * seed),
                              mean=np.zeros(F, np.float32),
                              scale=np.ones(F, np.float32))
        scores = x @ w + 0.1 * seed
        curve = build_r_curve(scores, scores > np.median(scores))
        proxy = ProxyModel(pred_idx=0, d=(), family="linear", params=params,
                           r_curve=curve, cost=1e-4)
        stage = PlanStage(pred_idx=0, proxy=proxy, alpha=0.9,
                          threshold=float(np.median(scores)))
        return PhysicalPlan(query=q, stages=[stage])

    seen_ids, reused = [], 0
    for seed in range(40):
        # drop every strong ref the caches hold so CPython can recycle
        # the NamedTuple's address between iterations
        ops._PACK_CACHE.clear()
        ops._OPERAND_CACHE.clear()
        ops._SCORER_CACHE.clear()
        gc.collect()
        plan = fresh_plan(seed)
        pid = id(plan.stages[0].proxy.params)
        reused += int(pid in seen_ids)
        seen_ids.append(pid)
        scorer, _hit = cascade_scorer_for_plan(plan)
        expect = (x @ plan.stages[0].proxy.params.w
                  + plan.stages[0].proxy.params.b) >= plan.stages[0].threshold
        got = scorer.score_masks(x)[:, 0]
        assert np.array_equal(got, np.asarray(expect)), (
            f"stale scorer served for recycled id at seed {seed}")
        del plan, scorer
    assert reused > 0, "test never provoked id reuse; tighten the loop"


def test_scorer_cache_hits_on_identical_content(workload, mixed_plan):
    """Content keying also dedupes: a deserialized copy of a plan this
    process already compiled is a cache HIT (same packed bytes), even
    though every params object differs."""
    ds, q = workload
    from repro.kernels import ops

    ops._SCORER_CACHE.clear()
    s1, hit1 = cascade_scorer_for_plan(mixed_plan)
    plan2, _ = deserialize_scorer(serialize_scorer(mixed_plan, s1), q)
    s2, hit2 = cascade_scorer_for_plan(plan2)
    assert not hit1 and hit2
    assert s1 is s2


# --------------------------------------------------- merged reservoirs
@given(
    n_rows=st.integers(16, 120),
    n_hosts=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_merged_reservoirs_match_single_reservoir(n_rows, n_hosts, seed):
    """Satellite property (ISSUE 4): splitting a labeled stream across K
    per-host reservoirs and merging the exports yields EXACTLY the same
    IPW-corrected selectivity as one reservoir fed the whole stream —
    order-insensitive, weights preserved."""
    rng = np.random.RandomState(seed)
    rows = rng.randn(n_rows, 3).astype(np.float32)
    sigma = rng.random_sample(n_rows) < 0.4
    weights = 1.0 / rng.uniform(0.05, 1.0, n_rows)  # arbitrary audit IPW
    assign = rng.randint(0, n_hosts, n_rows)

    single = Reservoir(n_preds=1, capacity=n_rows, stride=1)
    parts = [Reservoir(n_preds=1, capacity=n_rows, stride=1)
             for _ in range(n_hosts)]
    for i in range(n_rows):
        single.add(i, rows[i], force=True)
        single.observe(i, 0, bool(sigma[i]), weight=float(weights[i]))
        h = assign[i]
        parts[h].add(i, rows[i], force=True)
        parts[h].observe(i, 0, bool(sigma[i]), weight=float(weights[i]))
    merged = merge_reservoir_samples([p.export() for p in parts])
    perm = merge_reservoir_samples(
        [p.export() for p in reversed(parts)])  # order-insensitive
    want = ipw_selectivity(single.export(), 0)
    assert abs(ipw_selectivity(merged, 0) - want) < 1e-12
    assert abs(ipw_selectivity(perm, 0) - want) < 1e-12
    assert merged.n_rows == n_rows
    # weights rode through untouched
    order = np.argsort(merged.indices)
    assert np.allclose(merged.weights[order], weights, rtol=0, atol=0)


# ------------------------------------------------------ consensus protocol
def _vote(host, epoch=0, escalated=False, n_rows=4):
    rng = np.random.RandomState(host)
    return DriftVote(
        host=host, epoch=epoch,
        event=DriftEvent(at_record=100, signal=f"stage0:keep",
                         observed=0.1, expected=0.5, escalated=escalated),
        reservoir=ReservoirSample(
            indices=np.arange(n_rows) + 1000 * host,
            x=rng.randn(n_rows, 3).astype(np.float32),
            known_sigma={0: (np.ones(n_rows, bool),
                             rng.random_sample(n_rows) < 0.5)},
            weights=np.ones(n_rows),
        ),
    )


def test_quorum_sizes():
    assert quorum(1) == 1
    assert quorum(2) == 2
    assert quorum(3) == 2
    assert quorum(4) == 3
    assert quorum(5) == 3
    assert quorum(4, frac=0.75) == 4


def test_coordinator_vote_accounting(mixed_plan):
    coord = QuorumSwapCoordinator(
        mixed_plan, 4, reopt_fn=lambda plan, merged, mode: mixed_plan)
    assert not coord.offer_vote(_vote(0))
    assert not coord.offer_vote(_vote(0))  # duplicate host: ignored
    assert coord.votes_pending == 1
    assert not coord.offer_vote(_vote(1, epoch=3))  # stale/future epoch
    assert not coord.offer_vote(_vote(1))
    assert coord.offer_vote(_vote(2))  # 3rd distinct host = quorum of 3
    with pytest.raises(RuntimeError):  # propose() twice
        coord.propose()
        coord.propose()


def test_coordinator_two_phase_commit_and_abort(mixed_plan):
    reopts = []

    def reopt_fn(plan, merged, mode):
        reopts.append((merged.n_rows, mode))
        return mixed_plan

    coord = QuorumSwapCoordinator(mixed_plan, 3, reopt_fn=reopt_fn)
    for h in range(2):
        coord.offer_vote(_vote(h))
    prep = coord.propose(extra_reservoirs=[_vote(9).reservoir])
    assert prep.epoch == 1 and len(reopts) == 1
    assert reopts[0][0] == 12  # 2 votes + 1 extra, 4 rows each, merged
    # acks from 2 of 3 hosts: no commit yet (ALL hosts must ack)
    a = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=a)) is None
    assert coord.offer_ack(SwapAck(host=1, epoch=1, ok=True,
                                   attempt=a)) is None
    commit = coord.offer_ack(SwapAck(host=2, epoch=1, ok=True, attempt=a))
    assert commit is not None and commit.epoch == 1
    assert coord.epoch == 1 and coord.swaps_committed == 1
    assert coord.votes_pending == 0  # round cleared
    # next round: a NACK aborts and leaves the epoch unchanged
    for h in range(2):
        coord.offer_vote(_vote(h, epoch=1))
    coord.propose()
    a = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=2, ok=True,
                                   attempt=a)) is None
    assert coord.offer_ack(
        SwapAck(host=1, epoch=2, ok=False, error="boom",
                attempt=a)) is None
    assert coord.pending is None and coord.epoch == 1
    assert [r.committed for r in coord.swap_log] == [True, False]
    assert coord.swap_log[-1].aborted_by == 1


def test_majority_escalated_votes_force_bnb(mixed_plan):
    modes = []
    coord = QuorumSwapCoordinator(
        mixed_plan, 3,
        reopt_fn=lambda p, m, mode: modes.append(mode) or mixed_plan,
        choose_mode=lambda p, fresh: "alloc")
    coord.offer_vote(_vote(0, escalated=True))
    coord.offer_vote(_vote(1, escalated=True))
    coord.propose()
    assert modes == ["bnb"]  # 2/2 escalated overrides the alloc decision


# ----------------------------------------------------- end-to-end sharded
@pytest.fixture(scope="module")
def sharded_run(workload):
    """One K=4 skewed-drift run with version tracking (shared across the
    conservation / protocol assertions below)."""
    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=_policy(), seed=3)
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    return srv, stats


def test_sharded_quorum_swap_fires(sharded_run):
    srv, stats = sharded_run
    assert stats.swaps_committed >= 1
    assert stats.votes_cast >= srv.coordinator.quorum_size
    assert stats.final_epoch == stats.swaps_committed
    assert stats.swaps_aborted == 0
    for r in stats.swap_log:
        assert r.committed
        assert len(r.voters) >= srv.coordinator.quorum_size
        assert r.lag_records == 0  # two-phase barrier closed before serving
        assert r.merged_rows > 0


def test_sharded_conservation_across_swaps(sharded_run):
    """Acceptance: every submitted row is emitted-or-rejected exactly
    once, under the plan version it was scored with, across a quorum
    swap."""
    srv, stats = sharded_run
    assert stats.submitted == stats.emitted + stats.rejected
    all_emitted = []
    for h in srv.hosts:
        e = h.engine
        assert len(e.emitted) == len(set(e.emitted))  # no dupes per host
        assert len(e.emitted) == len(e.emitted_versions)
        # each record served under the version current at ITS submission
        for i, v in zip(e.emitted, e.emitted_versions):
            assert h.submit_version[i] == v
        all_emitted.extend(e.emitted)
    assert len(all_emitted) == len(set(all_emitted))  # shards disjoint


def test_sharded_hosts_share_epoch(sharded_run):
    srv, stats = sharded_run
    epochs = {h.epoch for h in srv.hosts}
    assert epochs == {stats.final_epoch}
    versions = {h.engine.plan_version for h in srv.hosts}
    assert versions == {stats.final_epoch}


def test_single_drifted_shard_cannot_swap(workload):
    """Only one of four shards drifts: its vote alone must never reach
    the 3-host quorum — the global plan stays at epoch 0 even though the
    local detector fired."""
    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    drifted = make_sharded_drifting_streams(
        ds, 1, 600, 2200, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.0, seed=41)[0]
    calm = ds.x[1500:1500 + 2800]
    streams = [drifted.x, calm, calm.copy(), calm.copy()]
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=_policy(), seed=3)
    stats = srv.run_streams(streams, chunk=400)
    assert stats.votes_cast >= 1  # the drifted shard did fire locally
    assert stats.swaps_committed == 0
    assert stats.final_epoch == 0
    assert {h.epoch for h in srv.hosts} == {0}
    assert stats.submitted == stats.emitted + stats.rejected


def test_prepare_nack_aborts_fleetwide(workload):
    """A host that cannot stage the artifact NACKs; the epoch aborts for
    EVERYONE — no partial installs, serving continues on the old plan."""
    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=_policy(), seed=3)
    broken = srv.hosts[2]
    broken.prepare = lambda msg: SwapAck(host=2, epoch=msg.epoch, ok=False,
                                         error="simulated stage failure",
                                         attempt=msg.attempt)
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.swaps_aborted >= 1
    assert stats.swaps_committed == 0
    assert {h.epoch for h in srv.hosts} == {0}
    assert {h.engine.plan_version for h in srv.hosts} == {0}
    assert stats.submitted == stats.emitted + stats.rejected


def test_abort_then_recovery_commits(workload):
    """Regression: an aborted epoch must re-arm voting — a TRANSIENT NACK
    (host fails one prepare, then heals) may not permanently disable
    quorum swaps for hosts whose votes were cleared with the round."""
    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=_policy(), seed=3)
    flaky = srv.hosts[2]
    real_prepare, fails = flaky.prepare, [0]

    def prepare_once_broken(msg):
        if not fails[0]:
            fails[0] += 1
            return SwapAck(host=2, epoch=msg.epoch, ok=False,
                           error="transient stage failure",
                           attempt=msg.attempt)
        return real_prepare(msg)

    flaky.prepare = prepare_once_broken
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.swaps_aborted == 1
    assert stats.swaps_committed >= 1  # the fleet recovered and swapped
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    assert stats.final_epoch >= 1
    assert stats.submitted == stats.emitted + stats.rejected


def test_thread_transport_conservation(workload):
    """Thread-isolated hosts: same protocol across real thread boundaries,
    same conservation guarantee."""
    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 2, 600, 1600, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.2, seed=41)
    srv = ShardedCascadeServer(plan, 2, tile=256, policy=_policy(), seed=3,
                               transport="thread")
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.submitted == stats.emitted + stats.rejected
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}


SUBPROC = textwrap.dedent(
    """
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core import optimize
    from repro.data.synthetic import (
        make_dataset, make_query, make_sharded_drifting_streams, make_udfs)
    from repro.distributed.serving import ShardedCascadeServer
    from repro.serving.stats import AdaptivePolicy

    ds = make_dataset(n=7000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=41)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=41,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=42)
    plan = optimize(q, ds.x[:1200], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 4, 700, 2000, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    policy = AdaptivePolicy(cooldown_records=1024, min_reservoir=128,
                            threshold=50.0, audit_rate=0.03,
                            reservoir_capacity=512)
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=policy, seed=3)
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.submitted == stats.emitted + stats.rejected
    assert stats.swaps_committed >= 1, stats.votes_cast
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    print("SHARDED_OK", stats.swaps_committed, stats.final_epoch)
    """
)


@pytest.mark.slow
@pytest.mark.flaky  # cold-interpreter subprocess under a wall-clock timeout
def test_sharded_serving_subprocess():
    """Whole-fleet run inside an isolated OS process (the
    test_distribution harness pattern): the sharded server, quorum swap,
    and wire-format install all work from a cold interpreter."""
    r = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        cwd="/root/repo", timeout=560,
    )
    assert "SHARDED_OK" in r.stdout, (
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-3000:]}")
