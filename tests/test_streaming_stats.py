"""Streaming statistics must be chunking-invariant: feeding the same rows
in ANY split yields exactly the batch statistic (the adaptive serving
loop's drift signals are only trustworthy if the incremental estimators
agree with their batch definitions)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.correlation import StreamingKappa2, correlation_score
from repro.serving.stats import Reservoir, StreamingRate


def _random_chunks(n, n_chunks, rng):
    """Split range(n) into n_chunks contiguous pieces (some may be empty)."""
    cuts = sorted(rng.randint(0, n + 1) for _ in range(max(n_chunks - 1, 0)))
    bounds = [0] + list(cuts) + [n]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


@given(
    n=st.integers(8, 400),
    d1=st.integers(1, 6),
    d2=st.integers(1, 6),
    n_chunks=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_streaming_kappa2_matches_batch_any_chunking(n, d1, d2, n_chunks, seed):
    rng = np.random.RandomState(seed)
    col1 = rng.randint(0, d1, size=n)
    col2 = rng.randint(0, d2, size=n)
    sk = StreamingKappa2()
    for lo, hi in _random_chunks(n, n_chunks, rng):
        sk.update(col1[lo:hi], col2[lo:hi])
    batch = correlation_score(col1, col2, sample=n + 1)  # no subsampling
    assert abs(sk.value() - batch) < 1e-9, (sk.value(), batch)


@given(
    n=st.integers(1, 500),
    p=st.floats(0.0, 1.0),
    n_chunks=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_streaming_rate_matches_empirical_any_chunking(n, p, n_chunks, seed):
    rng = np.random.RandomState(seed)
    kept = rng.random_sample(n) < p
    sr = StreamingRate()
    for lo, hi in _random_chunks(n, n_chunks, rng):
        sr.update(int(kept[lo:hi].sum()), hi - lo)
    assert sr.seen == n
    assert sr.rate == kept.mean() if n else sr.rate == 0.0


def test_streaming_kappa2_empty_and_single_valued():
    sk = StreamingKappa2()
    assert sk.value() == 0.0
    sk.update(np.zeros(10, int), np.arange(10) % 3)
    # one column is constant -> min(d1, d2) < 2 -> zero, same as batch
    assert sk.value() == correlation_score(np.zeros(10, int), np.arange(10) % 3)


def test_reservoir_recency_and_labels():
    r = Reservoir(n_preds=2, capacity=8, stride=2)
    for i in range(64):
        r.add(i, np.full(3, i, np.float32))
    # strided ring: holds a subsample of the most recent capacity*stride rows
    x, known = r.sample()
    assert len(x) == 8
    assert x[:, 0].min() >= 64 - 8 * 2
    # labels attach only while the row is resident, keyed by global idx
    newest = int(x[:, 0].max())
    r.observe(newest, 0, True)
    r.observe(3, 0, True)  # long-evicted: must be ignored
    x2, known2 = r.sample()
    row_pos = int(np.flatnonzero(x2[:, 0] == newest)[0])
    assert known2[0][0][row_pos] and known2[0][1][row_pos]
    assert known2[0][0].sum() == 1
