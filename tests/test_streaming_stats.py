"""Streaming statistics must be chunking-invariant: feeding the same rows
in ANY split yields exactly the batch statistic (the adaptive serving
loop's drift signals are only trustworthy if the incremental estimators
agree with their batch definitions).  Plus: the importance-sampled audit
stream's IPW-corrected selectivities must stay unbiased, and the
cost-model regret escalation must re-open the order question exactly when
a re-allocation cannot fix the drift."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.correlation import StreamingKappa2, correlation_score
from repro.core.query import MLUDF, PhysicalPlan, PlanStage, Predicate, Query
from repro.serving.stats import (
    AdaptivePolicy,
    ImportanceAuditSampler,
    Reservoir,
    StreamingRate,
    estimate_order_regret,
    ipw_selectivity,
)


def _random_chunks(n, n_chunks, rng):
    """Split range(n) into n_chunks contiguous pieces (some may be empty)."""
    cuts = sorted(rng.randint(0, n + 1) for _ in range(max(n_chunks - 1, 0)))
    bounds = [0] + list(cuts) + [n]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


@given(
    n=st.integers(8, 400),
    d1=st.integers(1, 6),
    d2=st.integers(1, 6),
    n_chunks=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_streaming_kappa2_matches_batch_any_chunking(n, d1, d2, n_chunks, seed):
    rng = np.random.RandomState(seed)
    col1 = rng.randint(0, d1, size=n)
    col2 = rng.randint(0, d2, size=n)
    sk = StreamingKappa2()
    for lo, hi in _random_chunks(n, n_chunks, rng):
        sk.update(col1[lo:hi], col2[lo:hi])
    batch = correlation_score(col1, col2, sample=n + 1)  # no subsampling
    assert abs(sk.value() - batch) < 1e-9, (sk.value(), batch)


@given(
    n=st.integers(1, 500),
    p=st.floats(0.0, 1.0),
    n_chunks=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_streaming_rate_matches_empirical_any_chunking(n, p, n_chunks, seed):
    rng = np.random.RandomState(seed)
    kept = rng.random_sample(n) < p
    sr = StreamingRate()
    for lo, hi in _random_chunks(n, n_chunks, rng):
        sr.update(int(kept[lo:hi].sum()), hi - lo)
    assert sr.seen == n
    assert sr.rate == kept.mean() if n else sr.rate == 0.0


def test_streaming_kappa2_empty_and_single_valued():
    sk = StreamingKappa2()
    assert sk.value() == 0.0
    sk.update(np.zeros(10, int), np.arange(10) % 3)
    # one column is constant -> min(d1, d2) < 2 -> zero, same as batch
    assert sk.value() == correlation_score(np.zeros(10, int), np.arange(10) % 3)


# ------------------------------------------- importance-sampled audit (IPW)
@given(
    base_sel=st.floats(0.1, 0.9),
    coupling=st.floats(0.0, 0.8),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=8, deadline=None)
def test_importance_audit_ipw_estimates_unbiased(base_sel, coupling, seed):
    """On a stationary stream whose labels CORRELATE with proximity to the
    proxy threshold (the adversarial case for threshold-weighted
    sampling), the Horvitz-Thompson-corrected selectivity estimate stays
    unbiased, while the uncorrected audited mean drifts with the
    coupling."""
    rng = np.random.RandomState(seed)
    n, trials = 1500, 150
    margins = np.abs(rng.randn(n)).astype(np.float64)
    # labels more likely NEAR the threshold: the exact bias importance
    # sampling would inject if uncorrected
    near = margins < np.median(margins)
    p_true = np.clip(base_sel + coupling * (near - 0.5), 0.02, 0.98)
    sigma = rng.random_sample(n) < p_true
    truth = sigma.mean()

    sampler = ImportanceAuditSampler(rate=0.15, floor=0.25)
    corrected, uncorrected = [], []
    for _ in range(trials):
        sel, ipw = sampler.select(margins, n, rng)
        if not sel.any():
            continue
        corrected.append(float((sigma[sel] * ipw).sum() / ipw.sum()))
        uncorrected.append(float(sigma[sel].mean()))
    corr_err = abs(np.mean(corrected) - truth)
    unc_err = abs(np.mean(uncorrected) - truth)
    assert corr_err < 0.02, (corr_err, truth)
    if coupling > 0.3:  # sampling bias is real -> the correction is load-bearing
        assert unc_err > corr_err, (unc_err, corr_err)


def test_importance_audit_budget_and_floor():
    """Expected audit volume stays ~rate*N and no propensity falls below
    the floor (bounded IPW weights)."""
    rng = np.random.RandomState(0)
    margins = np.abs(rng.randn(5000))
    sampler = ImportanceAuditSampler(rate=0.05, floor=0.25)
    p = sampler.propensities(margins, len(margins))
    assert p.min() >= 0.25 * 0.05 - 1e-12
    assert abs(p.mean() - 0.05) < 0.01  # mean-normalized budget
    # degenerate margins (all equal / None) -> uniform rate
    assert np.allclose(sampler.propensities(np.zeros(10), 10), 0.05)
    assert np.allclose(sampler.propensities(None, 10), 0.05)


def test_reservoir_force_add_and_weighted_selectivity():
    """Audited rows force-added to the reservoir carry IPW weights; the
    weighted selectivity undoes the sampling bias exactly on a frozen
    example."""
    r = Reservoir(n_preds=1, capacity=8, stride=1000)  # stride: nothing strided in
    # 4 high-propensity (p=0.5 -> w=2) positives, 4 low (p=0.1 -> w=10) negatives
    for i in range(4):
        r.add(i, np.zeros(2, np.float32), force=True)
        r.observe(i, 0, True, weight=2.0)
    for i in range(4, 8):
        r.add(i, np.zeros(2, np.float32), force=True)
        r.observe(i, 0, False, weight=10.0)
    sel = r.selectivity(0, min_labels=8)
    assert abs(sel - (4 * 2.0) / (4 * 2.0 + 4 * 10.0)) < 1e-12
    assert r.selectivity(0, min_labels=9) is None  # below evidence floor
    # force-add of a resident idx is a no-op (no duplicate slot)
    assert r.add(3, np.zeros(2, np.float32), force=True)
    assert r.size == 8


# ---------------------------------------------- cost-model regret escalation
def _toy_plan(udf_costs, sels, order=None):
    """Minimal proxy-less plan: stage cost reduces to prefix * udf_cost, so
    the order optimum is driven purely by (selectivity, cost)."""
    preds = [
        Predicate(udf=MLUDF(name=f"u{i}", fn=lambda x: np.zeros(len(x), int),
                            cost=c), values=frozenset({1}))
        for i, c in enumerate(udf_costs)
    ]
    q = Query(preds, accuracy_target=0.9)
    order = tuple(range(len(preds))) if order is None else order
    stages = [PlanStage(pred_idx=p, proxy=None, alpha=1.0,
                        est_selectivity=sels[p]) for p in order]
    return PhysicalPlan(query=q, stages=stages)


def test_regret_escalation_order_inversion_picks_bnb():
    """A selectivity inversion the incumbent ORDER cannot survive: alloc
    alone cannot fix it (it keeps the order), so the policy must escalate
    to the B&B re-search."""
    plan = _toy_plan([5.0, 5.0], sels=[0.2, 0.9])  # order (0, 1) optimal
    policy = AdaptivePolicy(regret_tol=0.1)
    # drift inverts the selectivities -> (1, 0) now cheaper
    regret, best = estimate_order_regret(plan, {0: 0.9, 1: 0.2})
    assert best == (1, 0) and regret > 0.3
    mode, r = policy.choose_escalation(plan, {0: 0.9, 1: 0.2})
    assert mode == "bnb" and r == regret


def test_regret_escalation_large_shift_same_order_picks_alloc():
    """A LARGE rate shift that leaves the incumbent order optimal needs
    only a re-allocation — magnitude-based escalation would have paid for
    a full re-search here."""
    plan = _toy_plan([5.0, 5.0], sels=[0.2, 0.9])
    policy = AdaptivePolicy(regret_tol=0.1)
    # pred 0's selectivity triples (|shift| = 0.4 >> any magnitude tol)
    # but (0, 1) is still the cheapest order
    mode, regret = policy.choose_escalation(plan, {0: 0.6, 1: 0.9})
    assert mode == "alloc" and regret == 0.0


def test_regret_estimate_no_evidence_is_conservative():
    """No fresh selectivities -> zero regret -> cheap path."""
    plan = _toy_plan([5.0, 5.0], sels=[0.2, 0.9])
    regret, best = estimate_order_regret(plan, {})
    assert regret == 0.0 and best == plan.order


# ------------------------------------ force-add / stride-tick accounting
def test_force_add_consumes_no_stride_tick():
    """Regression (ISSUE 4 sweep): audited force-adds must not advance the
    stride gate's tick counter — otherwise every audit would silently
    shift which stream records get the stated 1/stride inclusion, and the
    strided subsample would under-cover the stream by the audit rate."""
    stride = 3
    plain = Reservoir(n_preds=1, capacity=256, stride=stride)
    noisy = Reservoir(n_preds=1, capacity=256, stride=stride)
    rng = np.random.RandomState(0)
    pattern_plain, pattern_noisy = [], []
    for i in range(90):
        pattern_plain.append(plain.add(i, np.zeros(2, np.float32)))
        pattern_noisy.append(noisy.add(i, np.zeros(2, np.float32)))
        # interleave force-adds at arbitrary points (audit arrivals)
        if rng.random_sample() < 0.4:
            noisy.add(10_000 + i, np.zeros(2, np.float32), force=True)
    assert pattern_plain == pattern_noisy  # forced adds never tick
    # the stated propensity holds exactly: every stride-th offer taken
    assert pattern_noisy == [(i % stride) == 0 for i in range(90)]


def test_force_add_of_strided_resident_keeps_single_slot():
    """A row that was strided in and later audited (force-added) must not
    occupy two slots or reset its labels/weight."""
    r = Reservoir(n_preds=1, capacity=16, stride=1)
    r.add(7, np.full(2, 7, np.float32))
    r.observe(7, 0, True, weight=4.0)
    assert r.add(7, np.full(2, 7, np.float32), force=True)  # audit arrives
    assert r.size == 1
    assert r.selectivity(0, min_labels=1) == 1.0
    exp = r.export()
    assert exp.weights.tolist() == [4.0]  # weight survived the force no-op


def test_reservoir_export_weights_match_inclusion_probabilities():
    """Regression (ISSUE 4 sweep): ``sample()`` used to drop the IPW
    weights, so any estimator over exported rows silently treated the
    threshold-tilted audit subset as uniform.  The export must carry
    weights such that the Horvitz-Thompson estimate over the export is
    unbiased on a stream whose labels correlate with audit propensity —
    the exact bias force-added audit rows inject."""
    rng = np.random.RandomState(3)
    n, trials = 1200, 60
    margins = np.abs(rng.randn(n))
    near = margins < np.median(margins)
    p_true = np.clip(0.45 + 0.5 * (near - 0.5), 0.02, 0.98)
    truth_est, naive_est = [], []
    sampler = ImportanceAuditSampler(rate=0.12, floor=0.25)
    truth = None
    for _ in range(trials):
        sigma = rng.random_sample(n) < p_true
        truth = p_true.mean()
        res = Reservoir(n_preds=1, capacity=4 * n, stride=2)
        sel, ipw = sampler.select(margins, n, rng)
        for i in range(n):
            res.add(i, np.zeros(1, np.float32))
        ai = np.flatnonzero(sel)
        for j, w in zip(ai, ipw):
            res.add(int(j), np.zeros(1, np.float32), force=True)
            res.observe(int(j), 0, bool(sigma[j]), weight=float(w))
        exp = res.export()
        known, sg = exp.known_sigma[0]
        w = exp.weights[known]
        truth_est.append(float((w * sg[known]).sum() / w.sum()))
        naive_est.append(float(sg[known].mean()))
        # the export's HT estimate must equal the reservoir's own
        assert abs(truth_est[-1] - res.selectivity(0, min_labels=1)) < 1e-12
        assert abs(truth_est[-1] - ipw_selectivity(exp, 0)) < 1e-12
    assert abs(np.mean(truth_est) - truth) < 0.03
    assert abs(np.mean(naive_est) - truth) > abs(np.mean(truth_est) - truth)


# --------------------------------------- regret under partial audit coverage
def test_regret_partial_audit_coverage_uses_stale_fallback():
    """Regression (ISSUE 4 sweep): a predicate with no audit labels yet
    must fall back to the plan's stale selectivity — never raise — and
    the fallback must actually be the stale value (fresh evidence for one
    stage alone cannot invent evidence for the others)."""
    plan = _toy_plan([5.0, 5.0, 5.0], sels=[0.2, 0.5, 0.9])
    # only pred 2 has fresh evidence: it collapsed to near-zero
    regret, best = estimate_order_regret(plan, {2: 0.05})
    assert best[0] == 2  # cheapest-first under (0.2, 0.5, 0.05)
    assert regret > 0.0
    # missing preds used stale sels: the same call with those values made
    # explicit must be numerically identical
    regret2, best2 = estimate_order_regret(plan, {0: 0.2, 1: 0.5, 2: 0.05})
    assert regret == regret2 and best == best2
    # empty evidence stays conservative, whatever the plan size
    assert estimate_order_regret(plan, {}) == (0.0, plan.order)
    # >6 stages exercises the greedy path with partial coverage too
    big = _toy_plan([5.0] * 7, sels=[0.5] * 7)
    r_big, order_big = estimate_order_regret(big, {3: 0.01})
    assert order_big[0] == 3 and r_big >= 0.0


def test_reservoir_recency_and_labels():
    r = Reservoir(n_preds=2, capacity=8, stride=2)
    for i in range(64):
        r.add(i, np.full(3, i, np.float32))
    # strided ring: holds a subsample of the most recent capacity*stride rows
    x, known = r.sample()
    assert len(x) == 8
    assert x[:, 0].min() >= 64 - 8 * 2
    # labels attach only while the row is resident, keyed by global idx
    newest = int(x[:, 0].max())
    r.observe(newest, 0, True)
    r.observe(3, 0, True)  # long-evicted: must be ignored
    x2, known2 = r.sample()
    row_pos = int(np.flatnonzero(x2[:, 0] == newest)[0])
    assert known2[0][0][row_pos] and known2[0][1][row_pos]
    assert known2[0][0].sum() == 1
