"""Minimal, dependency-free stand-in for the `hypothesis` API surface the
test suite uses (given / settings / assume / strategies.integers|floats|
sampled_from).

The container does not ship hypothesis and nothing may be pip-installed, so
tests/conftest.py registers this module under ``sys.modules["hypothesis"]``
when the real package is absent.  Sampling is deterministic: each test gets
its own RNG seeded from the test's qualified name, so runs are reproducible
and failures are reportable ("falsifying example" is printed before the
exception propagates).  The real package, when installed, always wins.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Unsatisfied(Exception):
    """Raised by assume()/filter() to discard the current example."""


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"
    function_scoped_fixture = "function_scoped_fixture"

    @staticmethod
    def all():
        return []


class SearchStrategy:
    def __init__(self, draw_fn, desc="strategy"):
        self._draw = draw_fn
        self._desc = desc

    def __repr__(self):
        return f"<{self._desc}>"

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)), f"{self._desc}.map")

    def filter(self, pred):
        def draw(rng):
            for _ in range(200):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()

        return SearchStrategy(draw, f"{self._desc}.filter")


def integers(min_value=0, max_value=None):
    lo = int(min_value)
    hi = int(max_value) if max_value is not None else lo + 2**31

    def draw(rng):
        u = rng.random()
        if u < 0.05:
            return lo
        if u > 0.95:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw, f"integers({lo}, {hi})")


def floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        u = rng.random()
        if u < 0.05:
            return lo
        if u > 0.95:
            return hi
        return lo + (hi - lo) * rng.random()

    return SearchStrategy(draw, f"floats({lo}, {hi})")


def sampled_from(elements):
    elems = list(elements)

    def draw(rng):
        return elems[rng.randrange(len(elems))]

    return SearchStrategy(draw, f"sampled_from({elems!r})")


def booleans():
    return sampled_from([False, True])


def just(value):
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies), "tuples")


def lists(elements, min_size=0, max_size=10, **_kw):
    def draw(rng):
        k = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(k)]

    return SearchStrategy(draw, "lists")


def settings(*_args, **kw):
    """Decorator recording max_examples etc.; other knobs are ignored."""

    def deco(fn):
        merged = dict(getattr(fn, "_shim_settings", {}))
        merged.update(kw)
        fn._shim_settings = merged
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(func):
        sig = inspect.signature(func)
        names = list(sig.parameters)
        # positional strategies fill the trailing parameters (hypothesis fills
        # from the right; fixtures occupy the leading ones)
        pos_names = names[len(names) - len(arg_strategies):] if arg_strategies else []
        strat = dict(zip(pos_names, arg_strategies))
        strat.update(kw_strategies)
        remaining = [p for n, p in sig.parameters.items() if n not in strat]

        @functools.wraps(func)
        def wrapper(*fixture_args, **fixture_kwargs):
            conf = getattr(wrapper, "_shim_settings", {})
            n_examples = int(conf.get("max_examples") or 25)
            seed0 = zlib.crc32(func.__qualname__.encode())
            ran = 0
            for i in range(n_examples * 10):
                if ran >= n_examples:
                    break
                rng = random.Random(seed0 + i)
                try:
                    drawn = {k: s.draw(rng) for k, s in strat.items()}
                except _Unsatisfied:
                    continue
                try:
                    func(*fixture_args, **fixture_kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except BaseException:
                    print(f"Falsifying example: {func.__name__}(**{drawn!r})",
                          file=sys.stderr)
                    raise
                ran += 1
            if ran == 0:
                raise RuntimeError(
                    f"{func.__name__}: every generated example was rejected by assume()"
                )

        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


def install():
    """Register this shim as ``hypothesis`` if the real package is missing."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0-shim"
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just",
                 "tuples", "lists"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
