"""Hypothesis property tests on the system's invariants (deliverable c)."""
import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

from repro.core.accuracy import alpha_frontier
from repro.core.cost import plan_cost
from repro.core.proxy import build_r_curve


# ---------------------------------------------------------------- R curves
@given(
    n_pos=st.integers(10, 400),
    n_neg=st.integers(10, 400),
    sep=st.floats(0.0, 3.0),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_r_curve_keep_rate_property(n_pos, n_neg, sep, seed):
    """For ANY score distribution, keeping >= threshold(alpha) keeps at least
    alpha of the positives it was measured on (Figure 4 semantics)."""
    rng = np.random.RandomState(seed)
    scores = np.concatenate([rng.normal(sep, 1, n_pos), rng.normal(0, 1, n_neg)])
    labels = np.concatenate([np.ones(n_pos, bool), np.zeros(n_neg, bool)])
    curve = build_r_curve(scores, labels, conf_z=0.0)
    for a in (0.8, 0.9, 0.95, 1.0):
        thr = curve.threshold_for(a)
        kept = np.mean(scores[labels] >= thr)
        assert kept >= a - 1e-9
    # reduction never exceeds the fraction of records below the max score
    assert np.all(curve.reductions <= 1.0)
    assert np.all(curve.reductions >= 0.0)


# ------------------------------------------------------------ cost model
@given(
    n=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
@settings(max_examples=40, deadline=None)
def test_plan_cost_monotone_in_reduction(n, seed):
    """More reduction at any stage never increases the plan cost."""
    rng = np.random.RandomState(seed)
    alphas = rng.uniform(0.9, 1.0, n)
    sels = rng.uniform(0.2, 0.9, n)
    pc = rng.uniform(1e-4, 1e-2, n)
    uc = rng.uniform(1.0, 50.0, n)
    reds = rng.uniform(0.0, 0.9, n)
    base = plan_cost(alphas, reds, sels, pc, uc)
    i = rng.randint(n)
    reds2 = reds.copy()
    reds2[i] = min(1.0, reds2[i] + 0.05)
    assert plan_cost(alphas, reds2, sels, pc, uc) <= base + 1e-12


@given(n=st.integers(1, 3), A=st.floats(0.85, 0.98), seed=st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_plan_cost_monotone_in_alpha(n, A, seed):
    """With fixed reductions/selectivities, raising any alpha never lowers
    cost (the Lemma-1 premise; justifies searching the tight frontier)."""
    rng = np.random.RandomState(seed)
    alphas = rng.uniform(A, 1.0, n)
    sels = rng.uniform(0.2, 0.9, n)
    pc = rng.uniform(1e-4, 1e-2, n)
    uc = rng.uniform(1.0, 50.0, n)
    reds = rng.uniform(0.0, 0.9, n)
    base = plan_cost(alphas, reds, sels, pc, uc)
    i = rng.randint(n)
    a2 = alphas.copy()
    a2[i] = min(1.0, a2[i] + 0.02)
    assert plan_cost(a2, reds, sels, pc, uc) >= base - 1e-12


# --------------------------------------------------------- builder invariants
@given(seed=st.integers(0, 50))
@settings(max_examples=5, deadline=None)
def test_builder_never_labels_more_than_sample(seed):
    from repro.core.builder import ProxyBuilder
    from repro.data.synthetic import make_dataset, make_query, make_udfs

    ds = make_dataset(n=4000, correlation=0.8, seed=seed % 3)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=800, seed=seed % 3)
    q = make_query(ds, udfs, columns=[0, 1], seed=seed)
    b = ProxyBuilder(q, ds.x[:500], seed=seed)
    # exercise several relations in both orders
    b.rows_after_sigmas((0, 1))
    b.rows_after_sigmas((1, 0))
    b.rows_after_sigmas((1,))
    for pred, count in b.stats.udf_calls.items():
        assert count <= b.n, "lazy labeling must never exceed the sample size"


# ----------------------------------------------------------- serving engine
@given(
    tile=st.integers(16, 600),
    chunk=st.integers(50, 900),
    n=st.integers(200, 1200),
)
@settings(max_examples=8, deadline=None)
def test_cascade_conservation_property(tile, chunk, n):
    """No record lost or duplicated for ANY (tile, chunk, n) combination."""
    from repro.serving.engine import CascadeServer
    from repro.core.query import MLUDF, PhysicalPlan, PlanStage, Predicate, Query

    rng = np.random.RandomState(tile + chunk + n)

    def fn(x):
        return (x[:, 0] > 0).astype(np.int64)

    udf = MLUDF(name="u", fn=fn, cost=1.0)
    q = Query([Predicate(udf=udf, values=frozenset({1}))], 0.9)
    plan = PhysicalPlan(query=q, stages=[PlanStage(pred_idx=0, proxy=None)])
    x = rng.randn(n, 4).astype(np.float32)
    server = CascadeServer(plan, tile=tile, use_kernel=False)
    stats = server.run_stream(x, chunk=chunk)
    assert stats.emitted + stats.rejected == n
    assert sorted(server.emitted) == sorted(np.flatnonzero(fn(x) == 1).tolist())
