"""Quantized packed cascades + roofline autotune (ISSUE 6).

* quantize_cascade: symmetric per-column scales, int8 codes, folded
  readout — dequantized form tracks fp32 within the scale bound; the
  linear +/- trick's negation symmetry survives quantization exactly;
  pad columns stay inert (scale 1, zero codes);
* the fused kernel on int8 codes matches the quantized jnp oracle
  bit-for-bit, and the fp32 path is bit-identical to pre-quantization
  (the out_scale multiply is an IEEE identity at ones);
* decision-flip parity: masks differ from fp32 only on rows within the
  calibrated threshold tolerance;
* COREWIRE v1.2: quantized artifacts round-trip bit-exact at minor 2,
  fp32 artifacts keep minor 0 with an unchanged byte layout, unknown
  minors are rejected explicitly;
* compile-cache keys: same params at int8 vs fp32 are DISTINCT entries
  (no stale-dtype scorer), byte-identical quantized artifacts cache-HIT;
* autotune: full-tile hint reproduces the old static heuristic, small
  serving chunks choose smaller (faster-modeled) blocks, winners are
  cache-keyed (memory + disk), feasibility bound respected;
* estimate_order_regret is stable under quantization noise (<= tol, same
  chosen order), including the >6-stage greedy fallback;
* a quantized plan survives the full K=4 distributed path: quorum swap,
  hot-swap install, fused scoring — conservation and epoch agreement
  unchanged, dtype preserved through reoptimize + re-serialize.
"""
import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import execute_plan, optimize
from repro.core.proxy_family import (
    QUANT_WEIGHT_BYTES,
    cascade_kernel_operands,
    pack_cascade,
    quantize_cascade,
    unpack_cascade,
)
from repro.data.synthetic import (
    make_dataset,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.kernels import autotune, ref
from repro.kernels.ops import (
    CascadeScorer,
    WIRE_MINOR_QUANT,
    WireFormatError,
    cascade_scorer_for_plan,
    deserialize_frame,
    deserialize_scorer,
    quant_parity_report,
    serialize_scorer,
)
from repro.training.proxy_models import LinearParams, MLPParams

jnp = pytest.importorskip("jax.numpy")


def _linear(rng, F):
    return LinearParams(
        w=rng.randn(F).astype(np.float32), b=np.float32(rng.randn()),
        mean=rng.randn(F).astype(np.float32),
        scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32))


def _mlp(rng, F, H):
    return MLPParams(
        w1=rng.randn(F, H).astype(np.float32),
        b1=rng.randn(H).astype(np.float32),
        w2=rng.randn(H).astype(np.float32), b2=np.float32(rng.randn()),
        mean=rng.randn(F).astype(np.float32),
        scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32))


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=9000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=41)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=41,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=42)
    return ds, q


@pytest.fixture(scope="module")
def mixed_plan(workload):
    ds, q = workload
    return optimize(q, ds.x[:1200], mode="core-a", step=0.05, kind="mixed")


# --------------------------------------------------------- quantize math
@given(f=st.integers(3, 32), n_stages=st.integers(1, 4),
       seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_quantize_codes_scales_and_bound(f, n_stages, seed):
    """int8 codes bounded, scales positive, pad columns inert, and the
    dequantized cascade tracks the fp32 one within the per-column scale
    bound (half an int8 step in w1, one out_scale step in the readout)."""
    rng = np.random.RandomState(seed)
    params = [(_linear(rng, f) if rng.rand() < 0.5
               else _mlp(rng, f, rng.randint(1, 24)))
              for _ in range(n_stages)]
    packed = pack_cascade(params)
    qp = quantize_cascade(packed, "int8")
    assert qp.dtype == "int8" and qp.w1.dtype == np.int8
    assert qp.out_scale is not None and qp.out_scale.shape == (qp.n_stages,)
    assert np.all(qp.out_scale > 0)
    assert np.abs(qp.w1).max() <= 127 and np.abs(qp.w2).max() <= 127
    np.testing.assert_array_equal(qp.b2, packed.b2)  # biases never quantized
    # reconstruct: w1 codes * s1 must sit within s1/2 of the fp32 weights
    a1 = np.max(np.abs(packed.w1), axis=0)
    s1 = np.where(a1 > 0, a1 / 127.0, 1.0)
    assert np.all(np.abs(qp.w1 * s1[None] - packed.w1) <= s1[None] * 0.5 + 1e-7)
    # pad columns (hidden >= stage width) carry zero codes and scale 1
    for col, p in enumerate(params):
        h = packed.hidden[col]
        assert not qp.w1[:, h:, col].any()
        assert not qp.w2[h:, col].any()
        np.testing.assert_array_equal(s1[h:, col], 1.0)


def test_pm_trick_negation_survives_int8():
    """Paired +/- hidden columns of a linear stage share a max-abs, so
    their int8 codes are exact negations — the trick's cancellation
    property is preserved under quantization, not just approximated."""
    rng = np.random.RandomState(7)
    packed = quantize_cascade(pack_cascade([_linear(rng, 16)]), "int8")
    np.testing.assert_array_equal(packed.w1[:, 0, 0],
                                  -packed.w1[:, 1, 0].astype(np.int16)
                                  .astype(np.int8))
    assert packed.w2[0, 0] == -packed.w2[1, 0]


def test_fp8_codes_live_on_e4m3_grid():
    """fp8-simulated codes are exactly representable in float8_e4m3fn and
    clipped at the format's +-448 max."""
    ml_dtypes = pytest.importorskip("ml_dtypes")
    rng = np.random.RandomState(3)
    packed = quantize_cascade(pack_cascade([_mlp(rng, 12, 9)]), "fp8")
    assert packed.dtype == "fp8"
    for a in (packed.w1, packed.w2):
        grid = a.astype(ml_dtypes.float8_e4m3fn).astype(np.float32)
        np.testing.assert_array_equal(grid, a)
        assert np.abs(a).max() <= 448.0
    with pytest.raises(ValueError):
        quantize_cascade(packed, "int8")  # double-quantize is an error
    with pytest.raises(ValueError):
        quantize_cascade(pack_cascade([_mlp(rng, 4, 3)]), "int4")


def test_unpack_dequantizes_per_stage():
    """unpack_cascade of a quantized cascade returns the fp32-equivalent
    per-proxy form (readout folded through out_scale), so reference
    scoring of a wire-deserialized quantized proxy matches the kernel."""
    rng = np.random.RandomState(11)
    params = [_mlp(rng, 10, 6), _linear(rng, 10)]
    qp = quantize_cascade(pack_cascade(params), "int8")
    x = rng.randn(64, 10).astype(np.float32)
    w1, b1, w2, b2 = cascade_kernel_operands(qp)
    hid = np.maximum(x @ w1.astype(np.float32) + b1, 0.0)
    kernel_scores = hid @ w2.astype(np.float32) * qp.out_scale[None] + b2
    from repro.training.proxy_models import packed_score

    for col in range(2):
        pp = unpack_cascade(qp, col)
        assert pp.w2.dtype == np.float32
        np.testing.assert_allclose(packed_score(pp, x),
                                   kernel_scores[:, col], rtol=1e-5,
                                   atol=1e-4)


# ----------------------------------------------------------- kernel parity
def test_kernel_matches_quantized_oracle():
    """The fused kernel over int8 codes + out_scale agrees with the jnp
    oracle on the same quantized operands: masks bit-identical (the repo's
    standing fused-vs-ref contract), scores to f32 rounding (XLA picks
    M-dependent matmul micro-kernels, so the last-bit sum order differs
    between a 128-row tile and the full array)."""
    rng = np.random.RandomState(5)
    params = [_linear(rng, 20), _mlp(rng, 20, 7), _mlp(rng, 20, 3)]
    thr = rng.randn(3).astype(np.float32)
    x = rng.randn(300, 20).astype(np.float32)
    scorer = CascadeScorer(params, thr, block_m=128, max_tile=512,
                           dtype="int8")
    s, m, _pk, _cnt = scorer.score_compact(x, need_scores=True)
    w1, b1, w2, b2 = cascade_kernel_operands(scorer.packed)
    assert w1.dtype == np.int8 and w2.dtype == np.int8
    sref, mref, _ = ref.cascade_score_ref(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2),
        jnp.asarray(b2), jnp.asarray(thr),
        out_scale=jnp.asarray(scorer.packed.out_scale))
    np.testing.assert_allclose(s, np.asarray(sref), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(m, np.asarray(mref))


def test_fp32_path_bit_exact_through_out_scale_identity():
    """The ones out_scale multiply must not perturb a single bit of the
    fp32 path (x * 1.0 is an IEEE identity): the kernel with out_scale
    omitted and with explicit ones produces identical bytes, so every
    pre-quantization mask/score artifact is reproduced verbatim."""
    from repro.kernels.proxy_score import cascade_score

    rng = np.random.RandomState(9)
    params = [_linear(rng, 16), _mlp(rng, 16, 5)]
    thr = rng.randn(2).astype(np.float32)
    x = rng.randn(257, 16).astype(np.float32)
    scorer = CascadeScorer(params, thr, block_m=128, max_tile=512)
    assert scorer.dtype == "float32" and scorer.out_scale is None
    args = (jnp.asarray(x), scorer.w1, scorer.b1, scorer.w2, scorer.b2,
            scorer.thr, x.shape[0])
    s0, m0, _p0, _c0 = cascade_score(*args, block_m=128)
    s1, m1, _p1, _c1 = cascade_score(
        *args, out_scale=jnp.ones_like(scorer.b2), block_m=128)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))


def test_int8_scores_block_m_invariant():
    """Tiling must not change quantized results: the same int8 cascade
    scored at block 128 vs one 512-row tile is bit-identical per row."""
    rng = np.random.RandomState(21)
    params = [_mlp(rng, 24, 9), _linear(rng, 24)]
    thr = rng.randn(2).astype(np.float32)
    x = rng.randn(500, 24).astype(np.float32)
    s_a = CascadeScorer(params, thr, block_m=128, max_tile=512, dtype="int8")
    s_b = CascadeScorer(params, thr, block_m=512, max_tile=512, dtype="int8")
    sa, ma, _pa, _ca = s_a.score_compact(x, need_scores=True)
    sb, mb, _pb, _cb = s_b.score_compact(x, need_scores=True)
    np.testing.assert_array_equal(ma, mb)
    np.testing.assert_allclose(sa, sb, rtol=1e-6, atol=1e-6)


def test_quant_parity_flips_only_near_threshold(workload, mixed_plan):
    """The parity gate on a real mixed plan: every decision flip lies
    within the calibrated tolerance of a stage threshold, and per-stage
    selectivities barely move."""
    ds, _q = workload
    rep = quant_parity_report(mixed_plan, ds.x[1200:5200], dtype="int8")
    assert rep["flips_within_tol"]
    assert rep["max_sel_delta"] <= 0.02
    assert rep["max_err_eval"] <= 2.0 * rep["tol"]  # calib generalizes


def test_quant_end_to_end_accuracy_within_half_point(workload, mixed_plan):
    """Acceptance: end-to-end cascade accuracy within 0.5pt of fp32."""
    from repro.core import orig_plan

    ds, q = workload
    x = ds.x[1200:4200]
    plan_q = dataclasses.replace(
        mixed_plan, meta={**mixed_plan.meta, "quant_dtype": "int8"})
    truth = set(execute_plan(orig_plan(q), x).passed.tolist())
    acc_f = sum(1 for i in execute_plan(mixed_plan, x).passed.tolist()
                if i in truth) / max(len(truth), 1)
    acc_q = sum(1 for i in execute_plan(plan_q, x).passed.tolist()
                if i in truth) / max(len(truth), 1)
    assert abs(acc_f - acc_q) <= 0.005


# ------------------------------------------------------------ wire v1.2
def test_wire_quant_roundtrip_bit_exact(workload, mixed_plan):
    ds, q = workload
    plan_q = dataclasses.replace(
        mixed_plan, meta={**mixed_plan.meta, "quant_dtype": "int8"})
    blob = serialize_scorer(plan_q)
    assert int.from_bytes(blob[10:12], "little") == WIRE_MINOR_QUANT
    plan2, scorer2 = deserialize_scorer(blob, q)
    assert scorer2.dtype == "int8"
    assert plan2.meta["quant_dtype"] == "int8"
    assert scorer2.packed.w1.dtype == np.int8
    assert serialize_scorer(plan2, scorer2) == blob  # bit-exact round trip
    scorer1, _ = cascade_scorer_for_plan(plan_q)
    x = ds.x[2000:3000]
    np.testing.assert_array_equal(scorer1.score_masks(x),
                                  scorer2.score_masks(x))
    np.testing.assert_array_equal(
        np.asarray(scorer1.out_scale), np.asarray(scorer2.out_scale))


def test_wire_fp32_layout_unchanged_minor0(workload, mixed_plan):
    """fp32 artifacts must stay bit-for-bit what a v1.0 writer produced:
    minor 0, no quant header keys — an old blob deserializes unchanged."""
    import json

    ds, q = workload
    blob = serialize_scorer(mixed_plan)
    assert int.from_bytes(blob[10:12], "little") == 0
    hdr_len = int.from_bytes(blob[12:20], "little")
    header = json.loads(blob[20:20 + hdr_len].decode("utf-8"))
    assert "dtype" not in header["scorer"]
    assert "out_scale" not in header["scorer"]
    plan2, scorer2 = deserialize_scorer(blob, q)
    assert scorer2.dtype == "float32" and scorer2.out_scale is None
    assert "quant_dtype" not in plan2.meta
    assert serialize_scorer(plan2, scorer2) == blob


def test_wire_unknown_minor_rejected(workload, mixed_plan):
    ds, q = workload
    blob = serialize_scorer(mixed_plan)
    future = blob[:10] + (3).to_bytes(2, "little") + blob[12:]
    with pytest.raises(WireFormatError, match="unknown wire minor"):
        deserialize_scorer(future, q)
    framed = blob[:10] + (1).to_bytes(2, "little") + blob[12:]
    with pytest.raises(WireFormatError, match="control frame"):
        deserialize_scorer(framed, q)
    # and the frame channel refuses quantized artifacts symmetrically
    plan_q = dataclasses.replace(
        mixed_plan, meta={**mixed_plan.meta, "quant_dtype": "int8"})
    with pytest.raises(WireFormatError):
        deserialize_frame(serialize_scorer(plan_q))


# ------------------------------------------------- fingerprints / caching
def test_cache_key_distinct_per_dtype(workload, mixed_plan):
    """Same fp32 params packed at int8 vs fp32 must be DISTINCT cache
    entries — a stale-dtype scorer must never be served."""
    from repro.kernels import ops

    plan_q = dataclasses.replace(
        mixed_plan, meta={**mixed_plan.meta, "quant_dtype": "int8"})
    assert ops._plan_scorer_key(mixed_plan, 8192) != \
        ops._plan_scorer_key(plan_q, 8192)
    ops._SCORER_CACHE.clear()
    s_f, hit_f = cascade_scorer_for_plan(mixed_plan)
    s_q, hit_q = cascade_scorer_for_plan(plan_q)
    assert not hit_f and not hit_q and s_f is not s_q
    assert s_f.dtype == "float32" and s_q.dtype == "int8"
    # re-entry of each is its own hit
    assert cascade_scorer_for_plan(mixed_plan) == (s_f, True)
    assert cascade_scorer_for_plan(plan_q) == (s_q, True)


def test_identical_quant_artifacts_cache_hit(workload, mixed_plan):
    """Two byte-identical quantized artifacts deserialize to plans whose
    scorers share one compile-cache entry (content keying, dtype-aware)."""
    from repro.kernels import ops

    ds, q = workload
    plan_q = dataclasses.replace(
        mixed_plan, meta={**mixed_plan.meta, "quant_dtype": "int8"})
    blob = serialize_scorer(plan_q)
    plan_a, _ = deserialize_scorer(bytes(blob), q)
    plan_b, _ = deserialize_scorer(bytes(blob), q)
    ops._SCORER_CACHE.clear()
    s_a, hit_a = cascade_scorer_for_plan(plan_a)
    s_b, hit_b = cascade_scorer_for_plan(plan_b)
    assert not hit_a and hit_b and s_a is s_b


def test_params_fingerprint_unchanged_for_fp32(workload, mixed_plan):
    """Quantization plumbing must not churn existing fp32 fingerprints
    (they key live serving caches across plan swaps)."""
    from repro.kernels.ops import params_fingerprint

    p = mixed_plan.stages[0].proxy.params
    assert params_fingerprint(p) == params_fingerprint(p)


# --------------------------------------------------------------- autotune
def test_autotune_full_tile_matches_static_heuristic():
    """With no row hint the tuner reproduces the old static pick exactly
    — existing compiled-program caches and tests see no block change."""
    for (f, hp, p) in [(64, 128, 2), (64, 256, 4), (256, 1024, 8),
                       (32, 64, 2), (128, 2048, 16)]:
        static = autotune.static_heuristic_block_m(f, hp, p)
        cfg = autotune.choose_block_m(f, hp, p, "float32", backend="test")
        assert cfg.block_m == static, (f, hp, p)
        assert cfg.static_block_m == static


def test_autotune_small_chunk_picks_smaller_block():
    """A serving-chunk hint far below the static block chooses a smaller
    block with strictly less modeled time and fewer padded rows."""
    static = autotune.static_heuristic_block_m(64, 128, 2)
    assert static >= 2048  # precondition: the old rule over-blocks here
    cfg = autotune.choose_block_m(64, 128, 2, "int8", n_rows_hint=256,
                                  backend="test")
    assert cfg.block_m <= 256
    stat_cell = autotune.cell_model(64, 128, 2, "int8", static, 256)
    assert cfg.t_model_s < stat_cell.t_model_s
    assert cfg.bytes_moved < stat_cell.bytes_moved


def test_autotune_feasibility_and_weight_bytes():
    """Chosen blocks respect the 8MB per-block budget; int8 weight bytes
    are a quarter of fp32's in the model."""
    for hint in (None, 256, 8192):
        cfg = autotune.choose_block_m(256, 4096, 32, "float32",
                                      n_rows_hint=hint, backend="test")
        hpp = -(-4096 // 128) * 128
        pp = -(-32 // 128) * 128
        per_row = 4 * (256 + hpp) + 9 * pp
        assert per_row * cfg.block_m <= autotune.VMEM_BLOCK_BUDGET
    c_f = autotune.cell_model(64, 512, 4, "float32", 256, 256)
    c_q = autotune.cell_model(64, 512, 4, "int8", 256, 256)
    assert c_f.bytes_moved > c_q.bytes_moved
    assert QUANT_WEIGHT_BYTES["int8"] * 4 == QUANT_WEIGHT_BYTES["float32"]


def test_autotune_cache_hits_and_disk_persistence(tmp_path, monkeypatch):
    """Repeat lookups are cache hits (no re-sweep); with
    CORE_AUTOTUNE_CACHE set the table survives a cleared in-memory cache
    (a fresh process would skip the sweep too)."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("CORE_AUTOTUNE_CACHE", str(path))
    autotune.clear_autotune_cache()
    autotune.reset_autotune_stats()
    cfg1 = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="test")
    assert cfg1.source == "sweep"
    cfg2 = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="test")
    assert cfg2.source == "cache" and cfg2.block_m == cfg1.block_m
    stats = autotune.autotune_stats()
    assert stats["sweeps"] == 1 and stats["hits"] == 1
    assert path.exists()
    # simulate a fresh process: clear memory, reload from disk
    autotune.clear_autotune_cache()
    autotune.reset_autotune_stats()
    cfg3 = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="test")
    assert cfg3.source == "cache" and cfg3.block_m == cfg1.block_m
    assert autotune.autotune_stats()["sweeps"] == 0
    autotune.clear_autotune_cache()


def test_autotune_disk_cache_concurrent_writer_merges(tmp_path, monkeypatch):
    """K subprocess hosts share one CORE_AUTOTUNE_CACHE file.  A host
    that loaded the (empty) table BEFORE a peer's save lands must not
    clobber the peer's entries when it saves its own sweep: merge-on-save
    re-reads the file immediately before the atomic replace, so both
    shapes survive."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("CORE_AUTOTUNE_CACHE", str(path))
    # peer host sweeps shape B and publishes it
    autotune.clear_autotune_cache()
    cfg_b = autotune.choose_block_m(64, 384, 8, "float32", n_rows_hint=256,
                                    backend="test")
    assert len(autotune._read_disk_table(str(path))) == 1
    # our host: fresh memory, but it "loaded" the disk table before the
    # peer's save landed (the concurrent interleave) — its save must
    # still keep the peer's shape-B entry alongside our shape-A sweep
    autotune.clear_autotune_cache()
    autotune._DISK_LOADED = True
    cfg_a = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                    backend="test")
    assert cfg_a.source == "sweep"
    merged = autotune._read_disk_table(str(path))
    assert len(merged) == 2
    blocks = {(k[1], k[3]): v.block_m for k, v in merged.items()}
    assert blocks[(384, "float32")] == cfg_b.block_m
    assert blocks[(256, "int8")] == cfg_a.block_m
    # no temp-file litter from the atomic publish
    assert [p.name for p in tmp_path.iterdir()] == ["autotune.json"]
    autotune.clear_autotune_cache()


def test_autotune_disk_cache_tolerates_corrupt_file(tmp_path, monkeypatch):
    """A torn or unrelated file behind CORE_AUTOTUNE_CACHE must warn and
    fall back to a fresh sweep (never silently poison configs), and the
    next save replaces it with a valid table."""
    path = tmp_path / "autotune.json"
    path.write_text('{"torn prefix: [1, 2')
    monkeypatch.setenv("CORE_AUTOTUNE_CACHE", str(path))
    autotune.clear_autotune_cache()
    with pytest.warns(RuntimeWarning, match="corrupt or partial"):
        cfg = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                      backend="test")
    assert cfg.source == "sweep"
    table = autotune._read_disk_table(str(path))  # healed: parses again
    assert len(table) == 1 and next(iter(table.values())).block_m == cfg.block_m
    autotune.clear_autotune_cache()


def test_scorer_uses_autotuned_block(workload, mixed_plan):
    """CascadeScorer with a row hint adopts the tuner's block; without
    one it keeps the static heuristic's pick."""
    params = [s.proxy.params for s in mixed_plan.stages
              if s.proxy is not None]
    thrs = [s.threshold for s in mixed_plan.stages if s.proxy is not None]
    default = CascadeScorer(params, thrs)
    assert default.block_m == autotune.static_heuristic_block_m(
        default.n_features, int(default.w1.shape[1]), default.n_proxies)
    hinted = CascadeScorer(params, thrs, n_rows_hint=256)
    assert hinted.block_m <= 256


# ------------------------------------------------- backend calibration
@pytest.fixture()
def clean_backends():
    autotune.reset_backend_constants()
    autotune.clear_autotune_cache()
    yield
    autotune.reset_backend_constants()
    autotune.clear_autotune_cache()


def test_uncalibrated_backend_is_bit_identical(clean_backends):
    """The default path must not move: a backend with no registered
    constants scores every cell exactly as the nominal module constants
    do (existing block picks, caches, and tests see no change)."""
    for (f, hp, p, bm, rows) in [(64, 128, 2, 256, 512),
                                 (256, 4096, 32, 512, 8192)]:
        a = autotune.cell_model(f, hp, p, "float32", bm, rows)
        b = autotune.cell_model(f, hp, p, "float32", bm, rows,
                                backend="never-calibrated")
        assert a == b


def test_set_backend_constants_reprices_and_invalidates(clean_backends):
    """Registered constants change modeled time for THAT backend only,
    and evict its cached sweep winners (a winner picked under the
    nominal envelope may not survive the measured one)."""
    cfg1 = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="calib")
    assert cfg1.source == "sweep"
    assert autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="calib").source == "cache"
    other = autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                    backend="other")
    assert other.source == "sweep"
    slow = autotune.BackendConstants(hbm_bytes_per_s=1.2e10,
                                     peak_flops=7.0e11, source="measured")
    autotune.set_backend_constants("calib", slow)
    assert autotune.backend_constants("calib").source == "measured"
    # 100x slower roofs: same cell, much larger modeled time (the fixed
    # launch/grid overheads stay nominal, so the ratio lands below 100)
    base = autotune.cell_model(64, 256, 4, "int8", 256, 512)
    cal = autotune.cell_model(64, 256, 4, "int8", 256, 512,
                              backend="calib")
    assert cal.t_model_s > 5 * base.t_model_s
    # "calib" winners were evicted; "other" survived
    assert autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="calib").source == "sweep"
    assert autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                                   backend="other").source == "cache"


def test_calibrated_backend_never_touches_disk_cache(
        clean_backends, tmp_path, monkeypatch):
    """Measured constants are machine-local: winners picked under them
    must not be published to the shared disk table, where a host with
    different silicon would inherit them."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("CORE_AUTOTUNE_CACHE", str(path))
    autotune.set_backend_constants(
        "calib", autotune.BackendConstants(source="measured"))
    autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                            backend="calib")
    assert not path.exists()
    # a default-constants backend still persists as before
    autotune.choose_block_m(64, 256, 4, "int8", n_rows_hint=512,
                            backend="default-bk")
    assert path.exists()
    table = autotune._read_disk_table(str(path))
    assert {k[4] for k in table} == {"default-bk"}


def test_calibrate_backend_fits_and_registers(clean_backends, mixed_plan):
    """calibrate_backend fits positive constants from two measure_cell
    points and registers them: subsequent sweeps for that backend score
    under the measured envelope."""
    scorer, _ = cascade_scorer_for_plan(mixed_plan)
    bc = autotune.calibrate_backend(scorer, backend="calib-e2e",
                                    rows=(256, 4096), repeats=1)
    assert bc.source == "measured"
    assert bc.hbm_bytes_per_s > 0 and bc.peak_flops > 0
    assert bc.launch_overhead_s > 0
    # the default knee ratio is preserved (order-only compute roof)
    assert bc.peak_flops / bc.hbm_bytes_per_s == pytest.approx(
        autotune.PEAK_FLOPS / autotune.HBM_BYTES_PER_S)
    assert autotune.backend_constants("calib-e2e") == bc
    cfg = autotune.choose_block_m(
        scorer.n_features, int(scorer.w1.shape[1]), scorer.n_proxies,
        str(scorer.dtype), n_rows_hint=512, backend="calib-e2e")
    assert cfg.source == "sweep" and cfg.block_m >= 128


def test_calibrate_backend_register_false_leaves_registry(
        clean_backends, mixed_plan):
    scorer, _ = cascade_scorer_for_plan(mixed_plan)
    bc = autotune.calibrate_backend(scorer, backend="calib-dry",
                                    rows=(256, 2048), repeats=1,
                                    register=False)
    assert bc.source == "measured"
    assert autotune.backend_constants("calib-dry").source == "default"


# ------------------------------------------------- regret under quant noise
def _mask_sels(plan, masks):
    cols = {s.pred_idx: i for i, s in enumerate(plan.stages)}
    return {p: float(masks[:, cols[p]].mean()) for p in cols}


def test_regret_stable_under_quantization(workload, mixed_plan):
    """Escalation must not flap on quantization noise: selectivities
    measured from int8 masks feed estimate_order_regret to the same
    chosen order and nearly the same regret as fp32 masks."""
    from repro.serving.stats import estimate_order_regret

    ds, _q = workload
    x = ds.x[1200:4200]
    s_f = CascadeScorer.from_plan(mixed_plan, dtype="float32")
    s_q = CascadeScorer.from_plan(mixed_plan, dtype="int8")
    sel_f = _mask_sels(mixed_plan, s_f.score_masks(x))
    sel_q = _mask_sels(mixed_plan, s_q.score_masks(x))
    reg_f, order_f = estimate_order_regret(mixed_plan, sel_f)
    reg_q, order_q = estimate_order_regret(mixed_plan, sel_q)
    assert order_f == order_q
    assert abs(reg_f - reg_q) <= 0.02


def test_regret_greedy_fallback_stable_under_quant():
    """Same stability through the >6-stage greedy rank-ordering path
    (carried-over PR 3 follow-up)."""
    from repro.serving.stats import estimate_order_regret

    ds = make_dataset(n=6000, n_columns=7, correlation=0.85, seed=13)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=13,
                     declared_cost_ms=8.0)
    q = make_query(ds, udfs, columns=list(range(7)),
                   target_selectivity=0.5, seed=14)
    plan = optimize(q, ds.x[:1000], mode="core-a", kind="svm")
    assert len(plan.stages) == 7  # precondition: greedy fallback engaged
    x = ds.x[1000:3000]
    s_f = CascadeScorer.from_plan(plan, dtype="float32")
    s_q = CascadeScorer.from_plan(plan, dtype="int8")
    sel_f = _mask_sels(plan, s_f.score_masks(x))
    sel_q = _mask_sels(plan, s_q.score_masks(x))
    reg_f, order_f = estimate_order_regret(plan, sel_f)
    reg_q, order_q = estimate_order_regret(plan, sel_q)
    assert order_f == order_q
    assert abs(reg_f - reg_q) <= 0.02


# ---------------------------------------------------- distributed end-to-end
def test_quantized_plan_survives_sharded_swap(workload):
    """Acceptance: a quantized plan through the FULL distributed path —
    COREWIRE serialize, K=4 quorum-voted swap, hot-swap install, fused
    scoring — with conservation and epoch agreement intact, and the int8
    dtype preserved across the coordinator's reoptimize + re-broadcast."""
    from repro.distributed.serving import ShardedCascadeServer
    from repro.serving.stats import AdaptivePolicy

    ds, q = workload
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True,
                    quant_dtype="int8")
    blob = serialize_scorer(plan)
    assert int.from_bytes(blob[10:12], "little") == WIRE_MINOR_QUANT
    streams = make_sharded_drifting_streams(
        ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    policy = AdaptivePolicy(cooldown_records=1024, min_reservoir=128,
                            threshold=50.0, audit_rate=0.03,
                            reservoir_capacity=512)
    srv = ShardedCascadeServer(plan, 4, tile=256, policy=policy, seed=3)
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.swaps_committed >= 1
    assert stats.submitted == stats.emitted + stats.rejected  # conservation
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    for h in srv.hosts:
        state = h.engine._states[-1]
        assert state.plan.meta.get("quant_dtype") == "int8"
        assert state.cascade is not None and state.cascade.dtype == "int8"
        assert state.cascade.packed.w1.dtype == np.int8
