"""Tests: serving engine conservation, checkpoint roundtrip/reshard,
fault-tolerance components, data pipeline resume determinism."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import Cursor, Prefetcher, ShardedStream
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    ResilientRunner,
    StragglerDetector,
    compress_int8,
    decompress_int8,
)


# ------------------------------------------------------------ serving engine
@pytest.fixture(scope="module")
def small_plan():
    from repro.core import optimize, orig_plan
    from repro.data.synthetic import make_dataset, make_query, make_udfs

    ds = make_dataset(n=8000, correlation=0.85, feature_noise=1.0, seed=11)
    udfs = make_udfs(ds, hidden=32, depth=1, train_rows=1500, seed=11, declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=12)
    plan = optimize(q, ds.x[:1200], mode="core-a", step=0.05)
    return ds, q, plan


@pytest.mark.parametrize("tile", [64, 257, 1024])
def test_cascade_server_conservation(small_plan, tile):
    """Every submitted record is either emitted or rejected; none duplicated."""
    from repro.core import execute_plan
    from repro.serving.engine import CascadeServer

    ds, q, plan = small_plan
    x = ds.x[2000:5000]
    server = CascadeServer(plan, tile=tile, use_kernel=False)
    stats = server.run_stream(x, chunk=700)
    assert stats.emitted + stats.rejected == len(x)
    assert len(set(server.emitted)) == len(server.emitted)
    # same answer as the batch executor
    batch_res = execute_plan(plan, x)
    assert set(server.emitted) == set(batch_res.passed.tolist())


def test_cascade_server_kernel_path(small_plan):
    from repro.serving.engine import CascadeServer

    ds, q, plan = small_plan
    x = ds.x[2000:3000]
    a = CascadeServer(plan, tile=128, use_kernel=True).run_stream(x)
    b = CascadeServer(plan, tile=128, use_kernel=False).run_stream(x)
    assert a.emitted == b.emitted


# -------------------------------------------------------------- checkpointer
def test_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp

    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5, jnp.int32)}}
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(10, tree)
    ck.wait()
    restored = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_keeps_latest_and_gc(tmp_path):
    import jax.numpy as jnp

    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.full((2,), s)})
    assert ck.all_steps() == [3, 4]
    out = ck.restore({"x": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(out["x"]), [4, 4])


def test_checkpoint_integrity_check(tmp_path):
    import jax.numpy as jnp

    ck = Checkpointer(tmp_path, async_save=False)
    p = ck.save(1, {"x": jnp.ones(4)})
    shard = p / "shard_0.npz"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        ck.restore({"x": jnp.zeros(4)})


# ---------------------------------------------------------- fault tolerance
def test_heartbeat_monitor_detects_dead_host():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("h0")
    t[0] = 12.0
    assert mon.dead_hosts() == ["h1"]
    mon.beat("h1")
    assert mon.all_alive()


def test_straggler_detector_flags_outliers():
    d = StragglerDetector(threshold=2.0, warmup=3)
    for i in range(10):
        assert not d.observe(i, 1.0)
    assert d.observe(10, 5.0)  # 5x slower
    assert d.events == [10]
    assert not d.observe(11, 1.05)


def test_resilient_runner_restarts_and_remeshes(tmp_path):
    saved = {}
    fail_at = {7}
    devices = [4]

    def step_fn(state, step):
        if step in fail_at:
            fail_at.remove(step)
            raise RuntimeError("simulated device loss")
        return state + 1

    def save_fn(step, state):
        saved["ckpt"] = (step, state)

    def restore_fn():
        return saved["ckpt"]

    remeshed = []

    def remesh_fn(state, n):
        remeshed.append(n)
        return state

    save_fn(0, 0)
    runner = ResilientRunner(
        step_fn, save_fn, restore_fn, remesh_fn=remesh_fn,
        device_count_fn=lambda: devices[0], checkpoint_every=5, max_restarts=3,
    )
    # shrink the device pool mid-run
    orig_step = runner.step_fn

    def step_and_shrink(state, step):
        if step == 9:
            devices[0] = 2
        return orig_step(state, step)

    runner.step_fn = step_and_shrink
    state, report = runner.run(0, 20)
    assert report.restarts == 1
    assert report.remeshes == 1
    assert remeshed == [2]
    assert state == 20  # all 20 increments applied exactly once after replay
    assert saved["ckpt"][0] == 20


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_compression_bounded_error(n, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, 8).astype(np.float32) * rng.uniform(0.1, 10)
    import jax.numpy as jnp

    q, scale = compress_int8(jnp.asarray(x))
    rec = np.asarray(decompress_int8(q, scale))
    amax = np.abs(x).max(axis=-1, keepdims=True)
    assert np.all(np.abs(rec - x) <= amax / 127.0 + 1e-6)


# ------------------------------------------------------------- data pipeline
def test_sharded_stream_resume_determinism():
    data = np.arange(1000)
    s1 = ShardedStream(data, batch=7, seed=3)
    it = iter(s1)
    seen = [next(it) for _ in range(10)]
    cur = Cursor.from_dict(s1.cursor.as_dict())
    # resume a fresh stream from the saved cursor
    s2 = ShardedStream(data, batch=7, seed=3, cursor=cur)
    a, b = next(iter(s2)), next(it)
    np.testing.assert_array_equal(a, b)


def test_sharded_stream_hosts_disjoint():
    data = np.arange(100)
    got = []
    for h in range(4):
        s = ShardedStream(data, host_id=h, num_hosts=4, batch=5, seed=0)
        it = iter(s)
        for _ in range(5):  # one epoch worth per host (25 records / 5)
            got.append(next(it))
    flat = np.concatenate(got)
    assert len(flat) == 100
    assert len(np.unique(flat)) == 100  # no overlap between host shards


def test_prefetcher_passthrough():
    out = list(Prefetcher(iter(range(10)), depth=3))
    assert out == list(range(10))
