"""corelint engine tests: every rule covered by a fixture triple.

For each rule the fixture directory holds a violating file (exact rule id
and line asserted), a suppressed twin (the inline ``# corelint: disable``
must silence exactly that finding), and a clean twin (the idiomatic
rewrite must be silent).  A rule disabled via the ``enabled=`` set must
stop reporting — this is what makes each fixture a regression test for
the *rule*, not just for the fixture text.
"""
from pathlib import Path

import pytest

from repro.analysis.corelint import (
    RULE_IDS,
    RULES,
    apply_baseline,
    lint_source,
    run_corelint,
    write_baseline,
)

FIXTURES = Path(__file__).parent / "lint_fixtures"

#: fixture stem -> (relpath-under-lint_fixtures, rule id, violating line)
EXPECTED = {
    "wall-clock-decision": ("serving/wall_clock_bad.py", 6),
    "unseeded-randomness": ("serving/rng_bad.py", 6),
    "print-in-protocol": ("distributed/print_bad.py", 5),
    "host-sync-hot-path": ("hotpath/proxy_score_bad.py", 5),
    "identity-cache-key": ("generic/id_key_bad.py", 7),
    "atomic-persistence": ("generic/persist_bad.py", 6),
    "wire-pack-outside-ops": ("generic/wire_pack_bad.py", 5),
    "wire-minor-exhaustive": ("generic/wire_minor_bad.py", 7),
    "weights-travel": ("generic/weights_bad.py", 6),
    "deprecated-entry-point": ("serving/deprecated_bad.py", 6),
}


def _lint_fixture(rel, **kw):
    p = FIXTURES / rel
    # the relpath fed to the engine keeps the fixture's scope segments
    # (serving/, distributed/, ...) so path-scoped rules fire
    return lint_source(p.read_text(), f"tests/lint_fixtures/{rel}", **kw)


def test_every_rule_has_a_fixture():
    assert set(EXPECTED) == set(RULE_IDS)
    assert len(RULES) >= 8


@pytest.mark.parametrize("rule_id", sorted(EXPECTED))
def test_rule_fires_at_exact_line(rule_id):
    rel, line = EXPECTED[rule_id]
    violations, suppressed = _lint_fixture(rel)
    assert [(v.rule, v.line) for v in violations] == [(rule_id, line)]
    assert suppressed == 0


@pytest.mark.parametrize("rule_id", sorted(EXPECTED))
def test_disabling_the_rule_silences_it(rule_id):
    rel, _line = EXPECTED[rule_id]
    violations, _ = _lint_fixture(rel, enabled=RULE_IDS - {rule_id})
    assert violations == []


@pytest.mark.parametrize("rule_id", sorted(EXPECTED))
def test_inline_suppression_silences_exactly_one(rule_id):
    rel, _line = EXPECTED[rule_id]
    supp_rel = rel.replace("_bad.py", "_suppressed.py")
    violations, suppressed = _lint_fixture(supp_rel)
    assert violations == []
    assert suppressed == 1


@pytest.mark.parametrize("rule_id", sorted(EXPECTED))
def test_clean_twin_is_silent(rule_id):
    rel, _line = EXPECTED[rule_id]
    clean_rel = rel.replace("_bad.py", "_clean.py")
    violations, suppressed = _lint_fixture(clean_rel)
    assert violations == []
    assert suppressed == 0


def test_run_corelint_over_fixture_tree():
    report = run_corelint([FIXTURES], root=FIXTURES.parent.parent)
    assert report.files_scanned == 30
    assert report.parse_errors == []
    got = {(v.path.split("lint_fixtures/")[1], v.rule) for v in report.violations}
    assert got == {(rel, rid) for rid, (rel, _l) in EXPECTED.items()}
    assert report.suppressed == len(EXPECTED)


# ---------------------------------------------------------------- baseline


def test_baseline_masks_old_findings_not_new(tmp_path):
    old = '"""old"""\nx = id(object())\n'
    report_old, _ = lint_source(old, "pkg/mod.py")
    assert [v.rule for v in report_old] == ["identity-cache-key"]
    baseline = write_baseline(tmp_path / "base.json", report_old)
    # same file later grows a SECOND violation of the same rule
    new = '"""old"""\nx = id(object())\ny = id(object())\n'
    report_new, _ = lint_source(new, "pkg/mod.py")
    fresh, masked = apply_baseline(report_new, baseline)
    assert masked == 1
    assert [(v.rule, v.line) for v in fresh] == [("identity-cache-key", 3)]


def test_baseline_does_not_leak_across_rules_or_files(tmp_path):
    src = '"""m"""\nx = id(object())\n'
    violations, _ = lint_source(src, "pkg/a.py")
    baseline = write_baseline(tmp_path / "base.json", violations)
    other, _ = lint_source(src, "pkg/b.py")
    fresh, masked = apply_baseline(other, baseline)
    assert masked == 0
    assert len(fresh) == 1


def test_shipped_baseline_is_empty():
    import json

    shipped = Path(__file__).parent.parent / "corelint_baseline.json"
    assert json.loads(shipped.read_text()) == {}


# ---------------------------------------------------------------- the tree


def test_repo_tree_is_corelint_clean():
    """src/ and benchmarks/ lint clean with no baseline crutch."""
    root = Path(__file__).parent.parent
    report = run_corelint([root / "src", root / "benchmarks"], root=root)
    assert report.parse_errors == []
    assert [v.format() for v in report.violations] == []
