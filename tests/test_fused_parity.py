"""Fused-cascade parity: CascadeScorer masks and on-device-compacted
survivor indices must EXACTLY match the reference oracle, across ragged
tile sizes (N not a multiple of block_m), the P > 128 lane-pad path,
empty-survivor stages, MLP and mixed-family cascades (hidden-width
bucket boundaries included)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.proxy_family import cascade_kernel_operands
from repro.kernels import ref
from repro.kernels.ops import CascadeScorer, fold_standardizer
from repro.training.proxy_models import LinearParams, MLPParams


def _make_params(rng, F, P):
    """P independent LinearParams with nontrivial standardizers."""
    out = []
    for _ in range(P):
        out.append(LinearParams(
            w=rng.randn(F).astype(np.float32),
            b=np.float32(rng.randn()),
            mean=rng.randn(F).astype(np.float32),
            scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32),
        ))
    return out


def _make_mlp_params(rng, F, H):
    return MLPParams(
        w1=rng.randn(F, H).astype(np.float32),
        b1=rng.randn(H).astype(np.float32),
        w2=(rng.randn(H) / np.sqrt(H)).astype(np.float32),
        b2=np.float32(rng.randn()),
        mean=rng.randn(F).astype(np.float32),
        scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32),
    )


def _make_mixed_params(rng, F, P, max_hidden=33):
    """Alternating linear / MLP stages; MLP hidden widths deliberately
    straddle the bucket ladder (1, 2, 3, 4, 5, 8, 9, ... boundaries)."""
    widths = [1, 2, 3, 4, 5, 8, 9, 16, 17, 32, max_hidden]
    out = []
    for p in range(P):
        if p % 2 == 0:
            out.append(_make_params(rng, F, 1)[0])
        else:
            out.append(_make_mlp_params(rng, F, widths[p % len(widths)]))
    return out


def _reference(param_list, thresholds, x):
    """Pure-numpy oracle: standardize, score, threshold, compact."""
    masks = np.empty((x.shape[0], len(param_list)), bool)
    for p, (params, thr) in enumerate(zip(param_list, thresholds)):
        w, b = fold_standardizer(params)
        scores = x.astype(np.float32) @ w + b
        masks[:, p] = scores >= thr
    packed = [np.flatnonzero(masks[:, p]) for p in range(len(param_list))]
    return masks, packed


def _packed_reference(scorer, thresholds, x):
    """kernels/ref.py two-pass oracle on the scorer's OWN packed operands:
    the fused kernel must be bit-identical to this for every family."""
    w1, b1, w2, b2 = cascade_kernel_operands(scorer.packed)
    _s, masks, packed = ref.cascade_score_ref(
        jnp.asarray(x, jnp.float32), jnp.asarray(w1), jnp.asarray(b1),
        jnp.asarray(w2), jnp.asarray(b2),
        jnp.asarray(thresholds, jnp.float32))
    return np.asarray(masks), packed


@given(
    n=st.integers(1, 700),
    f=st.integers(4, 96),
    p=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_fused_matches_reference_ragged_shapes(n, f, p, seed):
    """N deliberately not tied to block_m: exercises row padding + masking."""
    rng = np.random.RandomState(seed)
    params = _make_params(rng, f, p)
    thresholds = rng.randn(p).astype(np.float32)
    x = rng.randn(n, f).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True,
                           max_tile=512)
    _scores, masks, packed, counts = scorer.score_compact(x)
    mref, pref = _reference(params, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    for col in range(p):
        assert counts[col] == len(pref[col])
        np.testing.assert_array_equal(packed[col], pref[col])


def test_fused_lane_pad_path_p_over_128():
    """P > 128 forces the 128-lane pad inside the kernel; padded columns
    must never leak into masks, packed indices, or counts."""
    rng = np.random.RandomState(7)
    F, P, N = 24, 130, 300
    params = _make_params(rng, F, P)
    thresholds = rng.randn(P).astype(np.float32)
    x = rng.randn(N, F).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True)
    _scores, masks, packed, counts = scorer.score_compact(x)
    mref, pref = _reference(params, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    for col in range(P):
        np.testing.assert_array_equal(packed[col], pref[col])


def test_fused_empty_survivor_stage():
    """A +inf threshold kills every record at one stage: its packed list is
    empty while the other stages are unaffected."""
    rng = np.random.RandomState(3)
    F, N = 16, 257  # N not a multiple of block_m
    params = _make_params(rng, F, 3)
    thresholds = np.asarray([-1e30, np.float32(np.finfo(np.float32).max), 0.0],
                            np.float32)
    x = rng.randn(N, F).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True)
    _scores, masks, packed, counts = scorer.score_compact(x)
    assert counts[0] == N and len(packed[0]) == N  # keep-all stage
    assert counts[1] == 0 and len(packed[1]) == 0  # empty-survivor stage
    assert not masks[:, 1].any()
    mref, pref = _reference(params, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    np.testing.assert_array_equal(packed[2], pref[2])


def test_fused_chunked_matches_single_tile():
    """Batches larger than max_tile are chunked; survivor indices must be
    globally offset correctly."""
    rng = np.random.RandomState(11)
    F, P, N = 20, 2, 1500
    params = _make_params(rng, F, P)
    thresholds = np.zeros(P, np.float32)
    x = rng.randn(N, F).astype(np.float32)
    small = CascadeScorer(params, thresholds, block_m=128, interpret=True,
                          max_tile=512)
    big = CascadeScorer(params, thresholds, block_m=128, interpret=True,
                        max_tile=4096)
    _, m1, p1, c1 = small.score_compact(x)
    _, m2, p2, c2 = big.score_compact(x)
    np.testing.assert_array_equal(m1, m2)
    for col in range(P):
        np.testing.assert_array_equal(p1[col], p2[col])
    np.testing.assert_array_equal(c1, c2)


def test_executor_fused_vs_reference_end_to_end():
    """Full plan execution: fused path returns the identical survivor set,
    stage bookkeeping, and flags the kernel path in StageStats."""
    from repro.core import execute_plan, optimize
    from repro.data.synthetic import make_dataset, make_query, make_udfs

    ds = make_dataset(n=6000, correlation=0.85, feature_noise=1.0, seed=21)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=21,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=22)
    plan = optimize(q, ds.x[:900], mode="core-a", step=0.05)
    x = ds.x[1500:4500]
    ref = execute_plan(plan, x, use_kernel=False)
    fus = execute_plan(plan, x, use_kernel=True, fused=True, batch_size=1024)
    assert set(ref.passed.tolist()) == set(fus.passed.tolist())
    assert abs(ref.model_cost_ms - fus.model_cost_ms) < 1e-6
    for a, b in zip(ref.stages, fus.stages):
        assert (a.n_in, a.n_proxy_kept, a.n_udf, a.n_pass) == \
            (b.n_in, b.n_proxy_kept, b.n_udf, b.n_pass)
        assert not a.used_kernel
    assert any(s.used_kernel for s in fus.stages if s.pred_idx is not None)
    assert fus.fused_score_ms > 0.0


# ------------------------------------------------- MLP / mixed cascades
@given(
    n=st.integers(1, 700),
    f=st.integers(4, 64),
    p=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=20, deadline=None)
def test_fused_mixed_cascade_matches_packed_reference(n, f, p, seed):
    """Mixed linear/MLP cascades, ragged N, hidden widths straddling the
    bucket ladder: fused masks, survivor indices, and counts must be
    bit-identical to the kernels/ref.py two-pass oracle."""
    rng = np.random.RandomState(seed)
    params = _make_mixed_params(rng, f, p)
    thresholds = rng.randn(p).astype(np.float32)
    x = rng.randn(n, f).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True,
                           max_tile=512)
    _scores, masks, packed, counts = scorer.score_compact(x)
    mref, pref = _packed_reference(scorer, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    for col in range(p):
        assert counts[col] == len(pref[col])
        np.testing.assert_array_equal(packed[col], pref[col])


def test_fused_mlp_lane_pad_path_p_over_128():
    """P > 128 MLP stages force the 128-lane pad on BOTH kernel dims (the
    stacked hidden dim and the stage dim); padded columns must never leak
    into masks, packed indices, or counts."""
    rng = np.random.RandomState(17)
    F, P, N = 12, 130, 300
    params = [_make_mlp_params(rng, F, 2) for _ in range(P)]
    thresholds = rng.randn(P).astype(np.float32)
    x = rng.randn(N, F).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True)
    _scores, masks, packed, counts = scorer.score_compact(x)
    mref, pref = _packed_reference(scorer, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    for col in range(P):
        np.testing.assert_array_equal(packed[col], pref[col])
        assert counts[col] == len(pref[col])


def test_fused_mixed_empty_survivor_stage():
    """+inf threshold on the MLP stage of a mixed cascade: its packed list
    is empty while the linear stages are unaffected."""
    rng = np.random.RandomState(23)
    F, N = 16, 257  # N not a multiple of block_m
    params = [_make_params(rng, F, 1)[0], _make_mlp_params(rng, F, 8),
              _make_params(rng, F, 1)[0]]
    thresholds = np.asarray(
        [-1e30, np.float32(np.finfo(np.float32).max), 0.0], np.float32)
    x = rng.randn(N, F).astype(np.float32)
    scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True)
    _scores, masks, packed, counts = scorer.score_compact(x)
    assert counts[0] == N and len(packed[0]) == N  # keep-all stage
    assert counts[1] == 0 and len(packed[1]) == 0  # empty MLP stage
    assert not masks[:, 1].any()
    mref, pref = _packed_reference(scorer, thresholds, x)
    np.testing.assert_array_equal(masks, mref)
    np.testing.assert_array_equal(packed[2], pref[2])


def test_fused_hidden_bucket_boundary_widths():
    """Hidden widths exactly at and one past each bucket boundary pack and
    score identically to the oracle (the pad slots must stay inert)."""
    rng = np.random.RandomState(29)
    F, N = 10, 200
    for h in (1, 2, 3, 4, 5, 8, 9, 16, 17, 32, 33):
        params = [_make_mlp_params(rng, F, h), _make_params(rng, F, 1)[0]]
        thresholds = rng.randn(2).astype(np.float32)
        x = rng.randn(N, F).astype(np.float32)
        scorer = CascadeScorer(params, thresholds, block_m=128, interpret=True)
        _s, masks, packed, counts = scorer.score_compact(x)
        mref, pref = _packed_reference(scorer, thresholds, x)
        np.testing.assert_array_equal(masks, mref)
        for col in range(2):
            np.testing.assert_array_equal(packed[col], pref[col])


def test_executor_mixed_fused_vs_reference_end_to_end():
    """Full mixed-cascade plan execution: the fused path returns the
    identical survivor set and runs EVERY proxied stage on the kernel —
    no silent reference fallback left for MLP stages."""
    from repro.core import execute_plan, optimize
    from repro.data.synthetic import make_dataset, make_query, make_udfs

    ds = make_dataset(n=6000, correlation=0.85, feature_noise=1.0, seed=51)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=51,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=52)
    plan = optimize(q, ds.x[:900], mode="core-a", step=0.05, kind="mixed")
    assert sorted(s.proxy.family for s in plan.stages) == ["linear", "mlp1"]
    x = ds.x[1500:4500]
    ref_res = execute_plan(plan, x, use_kernel=False)
    fus = execute_plan(plan, x, use_kernel=True, fused=True, batch_size=1024)
    # MLP standardizer folding is a f32 reassociation (~1e-4 agreement with
    # standardize-then-score), so exact-threshold records may flip; allow
    # boundary ties but nothing that could hide a real mask bug
    diff = set(ref_res.passed.tolist()) ^ set(fus.passed.tolist())
    assert len(diff) <= 3, f"{len(diff)} records disagree"
    assert abs(ref_res.model_cost_ms - fus.model_cost_ms) <= \
        1e-3 * ref_res.model_cost_ms
    for a, b in zip(ref_res.stages, fus.stages):
        for fa, fb in [(a.n_in, b.n_in), (a.n_proxy_kept, b.n_proxy_kept),
                       (a.n_udf, b.n_udf), (a.n_pass, b.n_pass)]:
            assert abs(fa - fb) <= 3
    assert all(s.used_kernel for s in fus.stages)


def test_mlp_plan_scorer_cache_hit_on_reswap():
    """Hot-swapping back to an MLP-bearing plan version is a scorer
    compile-cache hit (keyed on packed-param identity, family included)."""
    from repro.core import optimize
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.kernels.ops import cascade_scorer_for_plan

    ds = make_dataset(n=4000, correlation=0.85, seed=61)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=800, seed=61,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=62)
    plan_mlp = optimize(q, ds.x[:800], mode="core-a", step=0.05, kind="mlp")
    plan_mix = optimize(q, ds.x[:800], mode="core-a", step=0.05, kind="mixed")
    s1, hit1 = cascade_scorer_for_plan(plan_mlp)
    s2, hit2 = cascade_scorer_for_plan(plan_mix)
    s3, hit3 = cascade_scorer_for_plan(plan_mlp)  # re-swap
    s4, hit4 = cascade_scorer_for_plan(plan_mix)  # re-swap
    assert not hit1 and not hit2 and hit3 and hit4
    assert s1 is s3 and s2 is s4 and s1 is not s2
    assert all(c is not None for c in s1.stage_cols)  # MLP stages covered


def test_server_mixed_cascade_all_stages_kernel():
    """Serving engine on a mixed plan: every stage gates on the fused
    kernel path and output matches the reference engine."""
    from repro.core import optimize
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.serving.engine import CascadeServer

    ds = make_dataset(n=5000, correlation=0.85, feature_noise=1.0, seed=71)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=71,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=72)
    plan = optimize(q, ds.x[:800], mode="core-a", step=0.05, kind="mixed")
    x = ds.x[1000:4000]
    a = CascadeServer(plan, tile=257, use_kernel=True)
    sa = a.run_stream(x, chunk=700)
    b = CascadeServer(plan, tile=257, use_kernel=False)
    sb = b.run_stream(x, chunk=700)
    # boundary ties allowed (MLP fold reassociation), see executor test
    assert len(set(a.emitted) ^ set(b.emitted)) <= 3
    assert sa.emitted + sa.rejected == len(x)
    assert all(sa.stage_used_kernel)
    assert sa.fused_score_ms > 0.0


def test_server_fused_stats_and_parity():
    from repro.core import optimize
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.serving.engine import CascadeServer

    ds = make_dataset(n=5000, correlation=0.85, feature_noise=1.0, seed=31)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=31,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=32)
    plan = optimize(q, ds.x[:800], mode="core-a", step=0.05)
    x = ds.x[1000:4000]
    a = CascadeServer(plan, tile=257, use_kernel=True)
    sa = a.run_stream(x, chunk=700)
    b = CascadeServer(plan, tile=257, use_kernel=False)
    sb = b.run_stream(x, chunk=700)
    assert a.emitted == b.emitted
    assert sa.emitted + sa.rejected == len(x)
    assert all(sa.stage_used_kernel)
    assert not any(sb.stage_used_kernel)
    assert sa.fused_score_ms > 0.0
    assert abs(sa.model_cost_ms - sb.model_cost_ms) < 1e-6
