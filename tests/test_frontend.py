"""SLO-aware serving front end (DESIGN.md §7): conservation under random
arrivals/deadlines with a mid-stream plan hot-swap (property-style),
explicit deadline shedding, degrade-ladder pricing, goodput accounting,
and the ingestion guards (duplicate indices, zero-row requests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import optimize
from repro.core.cost import plan_cost
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.serving.engine import CascadeServer
from repro.serving.frontend import (
    ServingFrontEnd,
    SLOPolicy,
    degrade_ladder,
)


@pytest.fixture(scope="module")
def fe_workload():
    ds = make_dataset(n=8000, correlation=0.85, feature_noise=1.0, seed=11)
    udfs = make_udfs(ds, hidden=32, depth=1, train_rows=1500, seed=11,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=12)
    plan = optimize(q, ds.x[:1200], mode="core-a", step=0.05)
    return ds, plan


def _requests(fe, ds, rng, n_req, slo_factor, base=2000):
    """Enqueue n_req random-size requests with Poisson-ish arrivals;
    deadline scales with each request's own full-plan cost."""
    taken = 0
    req_ms = fe.engine.plan.est_total_cost
    arrival = 0.0
    for _ in range(n_req):
        rows = int(rng.randint(1, 220))
        idx = np.arange(base + taken, base + taken + rows)
        taken += rows
        arrival += float(rng.exponential(req_ms * rows))
        fe.submit_request(idx, ds.x[idx],
                          deadline_ms=float(slo_factor * req_ms * rows),
                          arrival_ms=arrival)


# --------------------------------------------------- conservation (property)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), slo_factor=st.floats(0.4, 4.0),
       swap=st.booleans())
def test_frontend_conservation_property(fe_workload, seed, slo_factor, swap):
    """Acceptance invariant: for ANY arrival pattern, deadline budget,
    and one mid-stream plan hot-swap, every record ends in exactly one of
    {emitted, rejected, explicitly shed}; no shed record is ever emitted;
    the engine pipeline is empty after drain.  Tight slo_factor draws
    force real shedding, loose ones force full service — both sides of
    the policy must conserve."""
    ds, plan = fe_workload
    rng = np.random.RandomState(seed)
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine)
    _requests(fe, ds, rng, n_req=int(rng.randint(3, 9)),
              slo_factor=slo_factor)
    swapped = degrade_ladder(plan)[1] if swap else None
    steps = 0
    while fe.step():
        steps += 1
        if swapped is not None and steps == 2:
            # external (e.g. quorum-decided) install, not a ladder move:
            # in-flight rows finish under the version that scored them
            engine.install_plan(swapped)
            fe.on_external_swap()
            swapped = None
    fe.drain()
    ok, why = fe.conserved()
    assert ok, why
    assert engine.in_flight() == 0
    emitted = set(engine.emitted)
    assert len(emitted) == len(engine.emitted)  # emitted-uniqueness
    n_total = emitted_total = shed_total = rejected_total = 0
    for req in fe.requests.values():
        assert req.done, f"rid {req.rid} never finished"
        if req.admission_rejected:
            # a refused request never touched the queue or the engine
            assert (req.cursor, req.submitted, req.emitted, req.shed) \
                == (0, 0, 0, 0)
            assert not req.met_slo
            rejected_total += req.n
            continue
        assert req.cursor == req.n
        assert req.submitted == req.emitted + req.rejected
        assert not (set(req.shed_ids) & emitted)
        n_total += req.n
        emitted_total += req.emitted
        shed_total += req.shed
    assert n_total == fe.stats.records_submitted + fe.stats.records_shed
    assert emitted_total == len(emitted)
    assert shed_total == fe.stats.records_shed
    assert rejected_total == fe.stats.records_rejected_admission


# ----------------------------------------------------------------- shedding
def test_frontend_sheds_expired_explicitly(fe_workload):
    """An impossible deadline is shed (reported, never silently dropped)
    and the request still completes — as an explicit SLO miss."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    # admission control off: this test exercises the mid-queue shed path,
    # which admission-time rejection would otherwise preempt
    fe = ServingFrontEnd(engine, policy=SLOPolicy(admission_control=False))
    idx = np.arange(2000, 2600)
    # the backlog request saturates the queue; the victim's deadline is
    # far below one row's service time so its tail must be shed
    fe.submit_request(idx, ds.x[idx],
                      deadline_ms=plan.est_total_cost * len(idx) * 10,
                      arrival_ms=0.0)
    vic = np.arange(2600, 2900)
    rid = fe.submit_request(vic, ds.x[vic], deadline_ms=1e-3,
                            arrival_ms=0.0)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    victim = fe.requests[rid]
    assert victim.done and victim.shed > 0
    assert not victim.met_slo  # shed work is an explicit miss
    assert fe.stats.requests_shed >= 1
    assert fe.stats.records_shed == victim.shed
    assert not (set(victim.shed_ids) & set(engine.emitted))


def test_frontend_no_shed_when_disabled(fe_workload):
    """shed_expired=False (the no-backpressure control) must serve every
    row even for expired requests — latency collapses, conservation
    holds, nothing is dropped."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine, policy=SLOPolicy(
        degrade=False, shed_expired=False, admission_control=False))
    idx = np.arange(2000, 2400)
    rid = fe.submit_request(idx, ds.x[idx], deadline_ms=1e-3,
                            arrival_ms=0.0)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    req = fe.requests[rid]
    assert req.done and req.shed == 0
    assert req.submitted == req.n
    assert not req.met_slo


# -------------------------------------------------------- admission control
def test_admission_rejects_unmeetable_deadline(fe_workload):
    """A request that cannot meet its deadline even at the CHEAPEST
    degrade rung is refused at admission: no queue slot, no engine work,
    counted as rejected — NOT as shed."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine)
    idx = np.arange(2000, 2300)
    rid = fe.submit_request(idx, ds.x[idx], deadline_ms=1e-3,
                            arrival_ms=0.0)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    req = fe.requests[rid]
    assert req.done and req.admission_rejected
    assert not req.met_slo
    # zero pipeline activity — rejection is cheaper than shedding
    assert (req.cursor, req.submitted, req.emitted, req.shed) == (0, 0, 0, 0)
    assert fe.stats.requests_rejected_admission == 1
    assert fe.stats.records_rejected_admission == len(idx)
    assert fe.stats.requests_shed == 0 and fe.stats.records_shed == 0
    assert engine.in_flight() == 0 and len(engine.emitted) == 0


def test_admission_admits_deadline_feasible_at_cheapest_rung(fe_workload):
    """A deadline infeasible at the full plan but feasible at the
    cheapest ladder rung must be ADMITTED — the degrade ladder is the
    mechanism that can still serve it."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine)
    cheapest = fe._cheapest_row_ms()
    full = fe._row_ms
    assert cheapest < full  # the ladder actually prices levels apart
    idx = np.arange(2000, 2100)
    # between the cheapest rung and the full plan: admissible, will
    # likely require degrading, but never rejected
    deadline = 0.5 * (cheapest + full) * len(idx)
    rid = fe.submit_request(idx, ds.x[idx], deadline_ms=deadline,
                            arrival_ms=0.0)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    req = fe.requests[rid]
    assert not req.admission_rejected
    assert req.done and req.cursor == req.n  # actually entered the queue
    assert fe.stats.requests_rejected_admission == 0


def test_admission_control_off_falls_back_to_shed(fe_workload):
    """With admission_control=False the same unmeetable request takes
    the legacy path: admitted, then shed by the deadline checker."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine, policy=SLOPolicy(admission_control=False))
    idx = np.arange(2000, 2300)
    rid = fe.submit_request(idx, ds.x[idx], deadline_ms=1e-3,
                            arrival_ms=0.0)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    req = fe.requests[rid]
    assert not req.admission_rejected
    assert req.shed > 0
    assert fe.stats.requests_rejected_admission == 0
    assert fe.stats.requests_shed == 1


# ------------------------------------------------------------ degrade ladder
def test_degrade_ladder_priced_with_eq31(fe_workload):
    """Each ladder level drops exactly one more trailing stage and is
    re-priced through Eq. 3.1 — est_total_cost strictly decreases and
    matches plan_cost on the surviving prefix."""
    _ds, plan = fe_workload
    ladder = degrade_ladder(plan, min_stages=1)
    assert len(ladder) == len(plan.stages)
    assert ladder[0] is plan
    for k, p in enumerate(ladder):
        assert len(p.stages) == len(plan.stages) - k
        assert list(p.stages) == list(plan.stages[:len(plan.stages) - k])
        assert p.meta.get("degrade_level", 0) == k
        expect = plan_cost(
            [s.alpha if s.proxy is not None else 1.0 for s in p.stages],
            [s.est_reduction if s.proxy is not None else 0.0
             for s in p.stages],
            [s.est_selectivity for s in p.stages],
            [s.proxy.cost if s.proxy is not None else 0.0 for s in p.stages],
            [plan.query.predicates[s.pred_idx].udf.cost for s in p.stages],
        )
        assert p.est_total_cost == pytest.approx(expect)
        if k:
            assert p.est_total_cost < ladder[k - 1].est_total_cost


def test_frontend_degrades_under_pressure_and_restores(fe_workload):
    """A burst past capacity pushes the ladder down (cheaper plan
    installed, counted); once the queue drains the ladder restores."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine)
    req_ms = plan.est_total_cost
    # burst: 6 back-to-back requests whose combined service exceeds any
    # single deadline at the full plan
    for r in range(6):
        idx = np.arange(2000 + r * 200, 2200 + r * 200)
        fe.submit_request(idx, ds.x[idx], deadline_ms=2.0 * req_ms * 200,
                          arrival_ms=r * 1e-3)
    # a late, generously-deadlined request: pressure is gone by then, so
    # the ladder must restore (restore is evaluated against PENDING work
    # — an idle front end stays parked at its last level)
    late = np.arange(4000, 4100)
    fe.submit_request(late, ds.x[late], deadline_ms=100.0 * req_ms * 100,
                      arrival_ms=50.0 * req_ms * 200)
    fe.run()
    ok, why = fe.conserved()
    assert ok, why
    assert fe.stats.degrades >= 1
    assert fe.stats.restores >= 1
    assert fe.stats.final_level == 0  # restored once the burst drained
    assert engine.stats.plan_swaps >= 2  # down and back up


# -------------------------------------------------------- goodput accounting
def test_frontend_goodput_accounting(fe_workload):
    """goodput_ratio is requests_met/requests_done and agrees with the
    per-request met_slo flags; an easy trace meets every deadline."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    fe = ServingFrontEnd(engine)
    req_ms = plan.est_total_cost
    for r in range(4):
        idx = np.arange(2000 + r * 100, 2100 + r * 100)
        fe.submit_request(idx, ds.x[idx], deadline_ms=50.0 * req_ms * 100,
                          arrival_ms=r * 5.0 * req_ms * 100)
    st_ = fe.run()
    met = sum(1 for q in fe.requests.values() if q.met_slo)
    assert st_.requests_done == 4
    assert st_.requests_met_slo == met == 4
    assert st_.goodput_ratio == 1.0
    assert st_.goodput_rps == pytest.approx(st_.throughput_rps)
    assert st_.served_ms > 0


# ------------------------------------------------------------------- guards
def test_frontend_rejects_duplicate_live_index(fe_workload):
    """Record indices identify rows end-to-end (emitted-uniqueness is a
    conservation clause), so re-submitting a live index must fail."""
    ds, plan = fe_workload
    fe = ServingFrontEnd(CascadeServer(plan, tile=128, use_kernel=False))
    idx = np.arange(2000, 2050)
    fe.submit_request(idx, ds.x[idx], deadline_ms=1e6)
    with pytest.raises(ValueError):
        fe.submit_request(idx[:10], ds.x[idx[:10]], deadline_ms=1e6)


def test_frontend_zero_row_request_completes(fe_workload):
    """A zero-row request must complete immediately (not deadlock the
    admit queue) and trivially meet its SLO."""
    ds, plan = fe_workload
    fe = ServingFrontEnd(CascadeServer(plan, tile=128, use_kernel=False))
    rid = fe.submit_request(np.arange(0), ds.x[:0], deadline_ms=10.0)
    fe.run()
    req = fe.requests[rid]
    assert req.done and req.met_slo
    ok, why = fe.conserved()
    assert ok, why


def test_frontend_empty_submit_does_not_inflate_counters(fe_workload):
    """Engine-level zero-row short-circuit: an idle tick's empty submit
    must not bump _records_submitted (which would skew the adaptive
    policy's cooldown bookkeeping)."""
    ds, plan = fe_workload
    engine = CascadeServer(plan, tile=128, use_kernel=False)
    before = engine._records_submitted
    engine.submit(np.arange(0), ds.x[:0])
    assert engine._records_submitted == before
