"""Distribution-layer tests: HLO analyzer (static fixture + compiled
module), sharding rules, and a reduced-config multi-device dry-run —
mesh-dependent parts run in a subprocess with a forced device count so this
test process keeps the default single device."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module, shape_bytes

FIXTURE = """\
HloModule jit_f, entry_computation_layout={(f32[32,256]{1,0})->f32[32,64]{1,0}}

%region_body (param: (s32[], f32[32,64], f32[10,128,64])) -> (s32[], f32[32,64], f32[10,128,64]) {
  %param = (s32[], f32[32,64]{1,0}, f32[10,128,64]{2,1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param), index=0
  %gte.1 = f32[32,64]{1,0} get-tuple-element(%param), index=1
  %gte.2 = f32[10,128,64]{2,1,0} get-tuple-element(%param), index=2
  %ag = f32[32,128]{1,0} all-gather(%gte.1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %ds = f32[1,128,64]{2,1,0} dynamic-slice(%gte.2, %gte.0), dynamic_slice_sizes={1,128,64}
  %bc = f32[128,64]{1,0} bitcast(%ds)
  %dot = f32[32,64]{1,0} dot(%ag, %bc), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple = (s32[], f32[32,64]{1,0}, f32[10,128,64]{2,1,0}) tuple(%gte.0, %dot, %gte.2)
}

%region_cond (param.1: (s32[], f32[32,64], f32[10,128,64])) -> pred[] {
  %param.1 = (s32[], f32[32,64]{1,0}, f32[10,128,64]{2,1,0}) parameter(0)
  %gte.3 = s32[] get-tuple-element(%param.1), index=0
  %c10 = s32[] constant(10)
  ROOT %lt = pred[] compare(%gte.3, %c10), direction=LT
}

ENTRY %main (p0: f32[32,64], p1: f32[10,128,64]) -> f32[32,64] {
  %p0 = f32[32,64]{1,0} parameter(0)
  %p1 = f32[10,128,64]{2,1,0} parameter(1)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[32,64]{1,0}, f32[10,128,64]{2,1,0}) tuple(%c0, %p0, %p1)
  %while = (s32[], f32[32,64]{1,0}, f32[10,128,64]{2,1,0}) while(%t), condition=%region_cond, body=%region_body
  ROOT %out = f32[32,64]{1,0} get-tuple-element(%while), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[32,64]{1,0}") == 32 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[])") == 20
    assert shape_bytes("pred[]") == 1


def test_analyzer_fixture_flops_and_collectives():
    comps, entry = parse_module(FIXTURE)
    assert set(comps) == {"region_body", "region_cond", "main"}
    assert entry == "main"
    c = analyze(FIXTURE)
    # dot inside the x10 while: 2*32*64*128 per iter
    assert c.flops == 10 * 2 * 32 * 64 * 128
    assert c.collective_bytes["all-gather"] == 10 * 32 * 128 * 4
    assert c.while_trip_counts == [10]
    # dynamic-slice priced at slice size, not the full stacked buffer
    assert c.hbm_bytes < 10 * (128 * 64 * 4 * 4 + 32 * 256 * 4 * 4) * 3


SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    from repro.configs import reduced_config
    from repro.distributed.sharding import (
        batch_sharding, cache_sharding, param_spec, params_shardings, opt_shardings)
    from repro.distributed import ctx
    from repro.models.registry import get_family, input_specs, make_batch
    from repro.training import optim
    from repro.training.train_loop import make_train_step

    # 1. rule sanity: col/row orientation + divisibility fallback
    assert param_spec(("layers", "attn", "wq"), (4, 64, 32), mesh, "train") == P(None, "data", "model")
    assert param_spec(("layers", "attn", "wo"), (4, 32, 64), mesh, "train") == P(None, "model", "data")
    assert param_spec(("layers", "attn", "wq"), (4, 63, 31), mesh, "train") == P(None, None, None)
    assert param_spec(("embed",), (256, 64), mesh, "serve_tp") == P("model", None)
    assert param_spec(("experts", "wg"), (4, 8, 64, 32), mesh, "serve_tp") == P(None, "model", None, None)

    # 2. end-to-end: reduced-config train step lowers + runs on the 8-dev mesh
    cfg = reduced_config("llama3-405b").replace(accum_steps=2)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params = fam.init(key, cfg)
    opt = optim.adamw_init(params)
    batch = make_batch(cfg, 8, 32, key)
    p_sh = params_shardings(params, mesh, "train")
    o_sh = opt_shardings(opt, mesh)
    b_sh = batch_sharding(batch, mesh)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)
    batch = jax.device_put(batch, b_sh)
    step = jax.jit(make_train_step(cfg, lr=1e-3), in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None))
    with ctx.use_mesh(mesh):
        p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), m
    # sharded result matches single-device result
    cfg1 = cfg
    step1 = jax.jit(make_train_step(cfg1, lr=1e-3))
    p1, o1, m1 = step1(jax.device_put(fam.init(key, cfg1)), optim.adamw_init(fam.init(key, cfg1)), make_batch(cfg1, 8, 32, key))
    assert abs(float(m["loss"]) - float(m1["loss"])) < 0.05, (float(m["loss"]), float(m1["loss"]))

    # 3. decode path with sharded cache
    specs = input_specs(cfg, type("S", (), {"kind": "decode", "seq_len": 64, "global_batch": 8, "name": "d"})())
    cache = jax.eval_shape(lambda: fam.init_cache(cfg, 8, 64))
    c_sh = cache_sharding(cache, mesh)
    assert jax.tree_util.tree_leaves(c_sh)

    # 4. elastic rescale: checkpoint saved on this mesh restores onto a
    #    DIFFERENT mesh shape with new shardings, values intact
    import tempfile
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(tempfile.mkdtemp(), async_save=False)
    ck.save(1, params)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    p_sh2 = params_shardings(params, mesh2, "train")
    restored = ck.restore(params, 1, shardings=p_sh2)
    a = jax.tree.leaves(params)[1]
    b = jax.tree.leaves(restored)[1]
    assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    some = [l for l in jax.tree.leaves(restored) if l.ndim >= 2][0]
    assert some.sharding.mesh.shape == {"data": 4, "model": 2}
    print("SUBPROC_OK")
    """
)


@pytest.mark.slow
@pytest.mark.flaky  # cold-interpreter subprocess under a wall-clock timeout
def test_multi_device_train_step_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        cwd="/root/repo", timeout=420,
    )
    assert "SUBPROC_OK" in r.stdout, f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-3000:]}"


def test_dryrun_results_all_ok():
    """The committed dry-run sweep must cover every runnable cell on both
    meshes with status ok (the 8 long_500k full-attention cells are skips)."""
    import json
    from pathlib import Path

    from repro.configs import cells

    base = Path("/root/repo/results/dryrun")
    if not base.exists():
        pytest.skip("dry-run sweep not yet executed")
    runnable = set(cells())
    for mesh in ("pod16x16", "pod2x16x16", "pod16x16_opt", "pod2x16x16_opt"):
        d = base / mesh
        if not d.exists():
            pytest.skip(f"{mesh} sweep missing")
        for arch, shape in runnable:
            f = d / f"{arch}__{shape}.json"
            assert f.exists(), f"missing dry-run cell {mesh}/{arch}x{shape}"
            rec = json.loads(f.read_text())
            assert rec["status"] == "ok", (mesh, arch, shape, rec.get("error"))
            r = rec["roofline"]
            assert r["t_compute_s"] > 0
            assert 0 < r["useful_flops_ratio"] <= 1.5, (arch, shape, r)


def test_opt_variant_never_worse_on_bound_by_much():
    """The §Perf opt variant must not regress any cell's step bound by >15%
    (analyzer noise); targeted cells must improve by the recorded factors."""
    import json
    from pathlib import Path

    base = Path("/root/repo/results/dryrun")
    if not (base / "pod16x16_opt").exists():
        pytest.skip("opt sweep missing")

    def bound(rec):
        r = rec["roofline"]
        return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])

    targets = {
        ("llama3-405b", "decode_32k"): 3.0,
        ("qwen3-moe-30b-a3b", "prefill_32k"): 5.0,
        ("deepseek-coder-33b", "prefill_32k"): 2.0,
        ("deepseek-v2-lite-16b", "prefill_32k"): 5.0,
    }
    from repro.configs import cells

    for arch, shape in cells():
        b = json.loads((base / "pod16x16" / f"{arch}__{shape}.json").read_text())
        o = json.loads((base / "pod16x16_opt" / f"{arch}__{shape}.json").read_text())
        if b["status"] != "ok" or o["status"] != "ok":
            continue
        ratio = bound(b) / max(bound(o), 1e-30)
        assert ratio > 0.85, f"opt regressed {arch}x{shape}: {ratio:.2f}x"
        if (arch, shape) in targets:
            assert ratio >= targets[(arch, shape)], (
                f"{arch}x{shape}: expected >= {targets[(arch, shape)]}x, got {ratio:.2f}x"
            )
