"""Adaptive serving: warm-started B&B resume, drift-triggered mid-stream
plan swaps (conservation under versioned masks), and the end-to-end
throughput/accuracy win on an order-inverting drifting stream."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # benchmarks/

from repro.core import BranchAndBound, ProxyBuilder, optimize, reoptimize
from repro.data.synthetic import (
    make_dataset,
    make_drifting_stream,
    make_query,
    make_udfs,
)
from repro.serving.engine import CascadeServer
from repro.serving.stats import AdaptivePolicy


@pytest.fixture(scope="module")
def drift_workload():
    ds = make_dataset(n=9000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=41)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=41,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=42)
    stream = make_drifting_stream(
        ds, 3000, 9000, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, seed=41,
    )
    return ds, q, stream


def _plan(q, ds, rows=1500):
    return optimize(q, ds.x[:rows], mode="core", step=0.05, keep_state=True)


# --------------------------------------------------------- warm-started B&B
def test_resume_unchanged_stats_identical_plan_no_work(drift_workload):
    """resume() with no new builder: the persisted candidate set and node
    states are final — identical plan, zero new L/M visits (trivially <=
    the cold search's count)."""
    ds, q, _ = drift_workload
    plan = _plan(q, ds)
    bb = plan.meta["bnb"]
    cold_visits = plan.meta["trace"]["nodes_visited"]
    alloc, tr = bb.resume()
    assert alloc.order == plan.order
    assert alloc.alphas == tuple(s.alpha for s in plan.stages)
    assert tr.nodes_visited == 0
    assert tr.nodes_visited <= cold_visits


def test_resume_on_drifted_stats_visits_fewer_nodes():
    """Warm resume against a drifted sample re-searches (the drift inverts
    the order optimum, and the resume finds the same order a cold search
    does), but the previous tree's slack-widened bounds still prune
    harder: strictly fewer L/M node visits than cold-starting."""
    from benchmarks.bench_adaptive import drift_scenario

    ds, q, stream = drift_scenario(n_before=3_000, n_after=6_000)
    plan = optimize(q, ds.x[:2000], mode="core", step=0.05, keep_state=True)
    drifted = stream.x[stream.boundary:stream.boundary + 2000]
    warm_builder = plan.meta["builder"].rebase(drifted)
    warm_alloc, warm_tr = plan.meta["bnb"].resume(warm_builder)
    cold_builder = ProxyBuilder(q, drifted, seed=0)
    cold_alloc, cold_tr = BranchAndBound(
        cold_builder, q.accuracy_target, step=0.05).run()
    assert warm_tr.nodes_visited >= 1  # it actually re-measured something
    assert warm_tr.nodes_visited < cold_tr.nodes_visited
    # adapted, not stale-stuck: both searches agree the drift moved a new
    # predicate to the front (the tail can differ on near-ties)
    assert warm_alloc.order != plan.order
    assert warm_alloc.order[0] == cold_alloc.order[0]
    assert len(warm_alloc.order) == q.n


def test_reoptimize_alloc_bumps_version_keeps_query(drift_workload):
    ds, q, stream = drift_workload
    plan = _plan(q, ds)
    fresh = stream.x[stream.boundary:stream.boundary + 1000]
    new = reoptimize(plan, fresh, mode="alloc")
    assert new.meta["plan_version"] == plan.meta["plan_version"] + 1
    assert new.query is q
    assert sorted(new.order) == sorted(plan.order)
    assert "builder" in new.meta  # state carried for the next warm resume


def test_scorer_compile_cache_hits_on_reswap(drift_workload):
    from repro.kernels.ops import cascade_scorer_for_plan

    ds, q, _ = drift_workload
    plan = optimize(q, ds.x[:800], mode="core-a", step=0.05)
    s1, hit1 = cascade_scorer_for_plan(plan)
    s2, hit2 = cascade_scorer_for_plan(plan)
    assert not hit1 and hit2
    assert s1 is s2


# ------------------------------------------------- mid-stream swap semantics
@pytest.mark.parametrize("tile,chunk", [(64, 400), (257, 700), (512, 2048)])
def test_adaptive_swap_conservation(drift_workload, tile, chunk):
    """Across drift-triggered hot swaps, every record is rejected-or-
    emitted exactly once: in-flight entries finish under their own plan
    version's mask rows (no mask-version mixups -> no loss, no dupes)."""
    ds, q, stream = drift_workload
    plan = _plan(q, ds)
    policy = AdaptivePolicy(
        cooldown_records=1024, min_reservoir=128, threshold=50.0,
        audit_rate=0.03, reservoir_capacity=512, escalate="bnb",
    )
    srv = CascadeServer(plan, tile=tile, use_kernel=True, adaptive=True,
                        policy=policy, seed=3)
    stats = srv.run_stream(stream.x, chunk=chunk)
    assert stats.plan_swaps >= 1  # the drift actually triggered a swap
    assert stats.emitted + stats.rejected == stream.n
    assert len(srv.emitted) == stats.emitted
    assert len(set(srv.emitted)) == len(srv.emitted)


def test_adaptive_off_is_bit_identical_to_static(drift_workload):
    """adaptive=False must stay the PR-1 engine: same emissions, no audit
    cost, no swaps — the adaptive machinery is pay-for-use."""
    ds, q, stream = drift_workload
    x = stream.x[:4000]
    a = CascadeServer(_plan(q, ds), tile=257, use_kernel=True)
    sa = a.run_stream(x, chunk=900)
    assert sa.plan_swaps == 0 and sa.audit_records == 0
    assert sa.emitted + sa.rejected == len(x)


# ------------------------------------------------------ end-to-end drift win
@pytest.mark.slow
def test_adaptive_beats_static_and_meets_accuracy():
    """Acceptance: >=1.2x cost-model throughput over the frozen plan on the
    drifting stream, accuracy target still met, warm resume strictly
    cheaper than cold B&B.  Same scenario the regression gate records in
    BENCH_components.json.

    Floor history: recorded 1.36 on the PR-2 container; the current
    toolchain trains fractionally different proxies (same swap record,
    same order flip) and lands at a deterministic 1.272, so the floor
    keeps ~0.07 of headroom below that instead of sitting above it.
    Keep in sync with ``min_adaptive_speedup`` in
    benchmarks/baseline_components.json."""
    from benchmarks.bench_adaptive import bench_adaptive_throughput

    out = bench_adaptive_throughput()
    assert out["plan_swaps"] >= 1
    assert out["adaptive_speedup"] >= 1.2, out
    assert out["adaptive_accuracy"] >= out["accuracy_target"], out
    assert out["warm_nodes"] < out["cold_nodes"], out
