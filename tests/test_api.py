"""PR 10 API redesign: ``OptimizeOptions``/``ServeConfig`` threading,
``CoreSession.serve`` dispatch, the deprecated entry-point shims, and
the golden CLI flag round-trip (every ``launch/serve.py`` flag maps
onto a typed config field through ``FLAG_MAP``)."""
import numpy as np
import pytest

from repro.core import (
    CoreSession,
    OptimizeOptions,
    PlanCache,
    ServeConfig,
    build_plan,
    optimize,
    rebuild_plan,
    reoptimize,
)
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.launch.serve import (
    _INVERTED,
    FLAG_MAP,
    build_arg_parser,
    config_from_args,
)


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=4000, correlation=0.9, seed=17)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=800, seed=17,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1], seed=18)
    return ds, udfs, q


OPTS = OptimizeOptions(mode="core-a", step=0.05, seed=17)


# -------------------------------------------------------- deprecated shims
def test_optimize_shim_warns_and_matches_build_plan(workload):
    ds, _, q = workload
    x = ds.x[:800]
    with pytest.warns(DeprecationWarning, match="build_plan"):
        p_old = optimize(q, x, mode="core-a", step=0.05, seed=17)
    p_new = build_plan(q, x, OPTS)
    assert list(p_old.order) == list(p_new.order)
    assert p_old.est_total_cost == pytest.approx(p_new.est_total_cost)


def test_reoptimize_shim_warns_and_matches_rebuild_plan(workload):
    ds, _, q = workload
    x = ds.x[:800]
    base = build_plan(q, x, OPTS.replace(keep_state=True))
    with pytest.warns(DeprecationWarning, match="rebuild_plan"):
        p_old = reoptimize(base, x, mode="alloc", step=0.05, seed=17)
    p_new = rebuild_plan(base, x, OPTS.replace(reopt="alloc",
                                              keep_state=True))
    assert list(p_old.order) == list(p_new.order)
    assert p_old.est_total_cost == pytest.approx(p_new.est_total_cost)


def test_warm_optimize_shim_warns_and_delegates(workload):
    ds, _, q = workload
    x = ds.x[:800]
    cache = PlanCache()
    with pytest.warns(DeprecationWarning, match="optimize_query"):
        plan, info = cache.warm_optimize(q, x, mode="core-a", step=0.05,
                                         seed=17)
    assert info["path"] == "cold" and plan is not None
    # the shim wrote through to the same cache the new API reads
    hit_plan, hit = cache.optimize_query(q, x, OPTS.replace(seed=17))
    assert hit["path"] == "hit"
    assert list(hit_plan.order) == list(plan.order)


# ------------------------------------------------------------ options plumbing
def test_options_replace_returns_new_instance():
    opts = OptimizeOptions(step=0.05)
    o2 = opts.replace(step=0.1, keep_state=True)
    assert (o2.step, o2.keep_state) == (0.1, True)
    assert (opts.step, opts.keep_state) == (0.05, False)
    cfg = ServeConfig()
    c2 = cfg.replace(slo_ms=200.0, hosts=4)
    assert (c2.slo_ms, c2.hosts) == (200.0, 4)
    assert (cfg.slo_ms, cfg.hosts) == (None, 1)


def test_register_query_normalizes_quant_dtype(workload):
    ds, _, q = workload
    s = CoreSession(options=OPTS)
    h32 = s.register_query(q, ds.x[:800], quant_dtype="fp32")
    h8 = s.register_query(q, ds.x[:800], quant_dtype="int8")
    assert h32.options.quant_dtype is None
    assert h8.options.quant_dtype == "int8"


# --------------------------------------------------------- CLI golden tests
#: one non-default value per flag — a FLAG_MAP typo cannot hide behind a
#: default because the round-trip asserts every dest moved
NON_DEFAULT_ARGV = [
    "--n", "5000", "--correlation", "0.7", "--accuracy", "0.85",
    "--mode", "core-a", "--proxy-kind", "mlp", "--quant-dtype", "int8",
    "--preds", "3", "--tile", "512", "--udf-cost-ms", "12.5",
    "--seed", "9", "--adaptive", "--drift", "--hosts", "2",
    "--drift-skew", "0.4", "--transport", "thread",
    "--kill-coordinator-at", "prepare", "--straggler-host", "1",
    "--slo-ms", "250", "--arrival-rate", "80", "--request-rows", "64",
    "--no-backpressure", "--plan-cache", "/tmp/pc.bin",
    "--queries", "/tmp/q.json",
]


def test_flag_map_covers_every_cli_flag():
    parser = build_arg_parser()
    dests = {a.dest for a in parser._actions} - {"help"}
    assert dests == set(FLAG_MAP)


def test_every_cli_flag_round_trips_into_config():
    parser = build_arg_parser()
    args = parser.parse_args(NON_DEFAULT_ARGV)
    defaults = parser.parse_args([])
    cfg = config_from_args(args)
    sections = {"workload": cfg.workload, "optimize": cfg.optimize,
                "serve": cfg.serve}
    for dest, (sec, fld) in FLAG_MAP.items():
        want = getattr(args, dest)
        assert want != getattr(defaults, dest), \
            f"--{dest}: NON_DEFAULT_ARGV left it at its default"
        if dest in _INVERTED:
            want = not want
        got = getattr(sections[sec], fld)
        assert got == want, (dest, sec, fld, got, want)


def test_cli_normalization_rules():
    parser = build_arg_parser()
    # fp32 means "no quantization pass", backpressure defaults ON
    cfg = config_from_args(parser.parse_args([]))
    assert cfg.optimize.quant_dtype is None
    assert cfg.serve.backpressure is True
    # CORE workload modes feed the optimizer; baseline modes do not
    cfg = config_from_args(parser.parse_args(["--mode", "core-h",
                                              "--seed", "5"]))
    assert cfg.workload.mode == "core-h"
    assert cfg.optimize.mode == "core-h"
    assert (cfg.workload.seed, cfg.optimize.seed, cfg.serve.seed) == \
        (5, 5, 5)
    cfg = config_from_args(parser.parse_args(["--mode", "pp"]))
    assert cfg.workload.mode == "pp"
    assert cfg.optimize.mode != "pp"


# ------------------------------------------------------------ serve dispatch
def test_serve_dispatch(workload):
    from repro.serving.engine import CascadeServer
    from repro.serving.frontend import ServingFrontEnd
    from repro.serving.multiquery import MultiQueryEngine

    ds, udfs, q = workload
    x = ds.x[:800]
    cache = PlanCache()  # shared: later sessions warm-hit the first build

    # single query, no SLO -> bare CascadeServer
    s1 = CoreSession(options=OPTS, plan_cache=cache)
    s1.register_query(q, x)
    assert isinstance(s1.serve(), CascadeServer)
    with pytest.raises(RuntimeError, match="already built"):
        s1.serve()
    with pytest.raises(RuntimeError, match="precede serve"):
        s1.register_query(q, x)

    # single query + SLO -> deadline-aware front end
    s2 = CoreSession(options=OPTS, plan_cache=cache)
    s2.register_query(q, x)
    assert isinstance(s2.serve(slo=200.0), ServingFrontEnd)

    # >= 2 queries -> shared MultiQueryEngine; sharded multi-query is a
    # filed follow-up, not a silent misconfiguration
    q2 = make_query(ds, udfs, columns=[1, 2], seed=19)
    s3 = CoreSession(options=OPTS, plan_cache=cache)
    s3.register_query(q, x)
    s3.register_query(q2, x)
    with pytest.raises(ValueError, match="ROADMAP"):
        s3.serve(hosts=2)
    assert isinstance(s3.serve(), MultiQueryEngine)


def test_query_handle_end_to_end(workload):
    ds, _, q = workload
    s = CoreSession(options=OPTS)
    h = s.register_query(q, ds.x[:800])
    assert h.plan is None
    plan = h.optimize()
    assert plan is h.plan and plan is not None
    s.serve()
    s.run_stream(ds.x[800:2400], chunk=512)
    st = h.stats()
    assert st["emitted"] + st["rejected"] == 1600
    with pytest.raises(KeyError):
        s.query_stats(1)
