"""Unit tests for the CORE optimizer machinery."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.accuracy import alpha_frontier
from repro.core.cost import Bounds, node_bounds, plan_cost, stage_cost
from repro.core.correlation import correlation_score
from repro.core.proxy import build_r_curve


# ------------------------------------------------------------- cost model
def test_stage_cost_matches_paper_example():
    """Paper §4.4: C(sigma1,alpha1) = 0.01 + (1 - 80/200)*20 = 12.01."""
    c = stage_cost(1.0, 0.01, 20.0, 80.0 / 200.0)
    assert abs(c - 12.01) < 1e-9


def test_eq_3_2_figure5_bookkeeping():
    """Figure 5: alpha1*delta1*alpha2*delta2 == A == 54/60."""
    alpha1, alpha2 = 96 / 100, 54 / 56
    s2, s2bar = 56 / 96, 60 / 100
    delta1, delta2 = 1.0, s2 / s2bar
    assert abs(alpha1 * delta1 * alpha2 * delta2 - 54 / 60) < 1e-9


def test_plan_cost_prefix_product():
    # two identical stages: second stage scaled by s1*alpha1
    c = plan_cost([0.9, 0.9], [0.5, 0.5], [0.5, 0.5], [0.01, 0.01], [10.0, 10.0])
    stage1 = 0.01 + 0.5 * 10
    stage2 = (0.5 * 0.9) * stage1
    assert abs(c - (stage1 + stage2)) < 1e-9


def test_lemma4_bounds_ordering():
    b = node_bounds(2, 0.9, 0.01, 10.0)
    assert b.lower <= b.upper
    assert b.lower >= 0
    # depth-0 node: prefix product is 1 for both bounds
    b0 = node_bounds(0, 0.9, 0.01, 10.0)
    assert abs(b0.lower - 0.01) < 1e-9  # r^u = 1 discards everything
    assert abs(b0.upper - 10.01) < 1e-9  # r^l = 0 discards nothing


def test_bounds_overlap():
    assert Bounds(0, 2).overlaps(Bounds(1, 3))
    assert not Bounds(0, 1).overlaps(Bounds(2, 3))


# ---------------------------------------------------------- alpha frontier
@given(
    n=st.integers(1, 4),
    A=st.floats(0.8, 0.98),
    step=st.sampled_from([0.02, 0.05]),
)
@settings(max_examples=25, deadline=None)
def test_alpha_frontier_products_near_target(n, A, step):
    cands = alpha_frontier(n, A, step)
    assert len(cands) > 0
    prods = np.prod(cands, axis=1)
    assert np.all(prods >= A - 1e-9)
    # tight shell: products below A/(1-step)
    assert np.all(prods < A / (1 - step) + 1e-9)
    # all coordinates within [A, 1]
    assert np.all(cands >= A - 1e-9) and np.all(cands <= 1.0 + 1e-9)


def test_alpha_frontier_contains_balanced():
    cands = alpha_frontier(2, 0.9, 0.02)
    bal = np.sqrt(0.9)
    d = np.abs(cands - bal).sum(axis=1).min()
    assert d < 0.06  # a near-balanced point exists on the grid


# ---------------------------------------------------------------- R curve
def test_r_curve_monotone_and_thresholds():
    rng = np.random.RandomState(0)
    scores = np.concatenate([rng.normal(1, 1, 500), rng.normal(-1, 1, 500)])
    labels = np.concatenate([np.ones(500, bool), np.zeros(500, bool)])
    curve = build_r_curve(scores, labels, conf_z=0.0)
    # reduction non-increasing as alpha rises
    assert np.all(np.diff(curve.reductions) >= -1e-9)
    # semantic check: keeping >= threshold(alpha) keeps >= alpha of positives
    for a in (0.9, 0.95, 0.99):
        thr = curve.threshold_for(a)
        kept = np.mean(scores[labels] >= thr)
        assert kept >= a - 1e-9, (a, kept)


def test_r_curve_confidence_margin_is_conservative():
    rng = np.random.RandomState(1)
    scores = np.concatenate([rng.normal(1, 1, 200), rng.normal(-1, 1, 200)])
    labels = np.concatenate([np.ones(200, bool), np.zeros(200, bool)])
    plain = build_r_curve(scores, labels, conf_z=0.0)
    safe = build_r_curve(scores, labels, conf_z=1.5)
    for a in (0.85, 0.9, 0.95):
        assert safe.threshold_for(a) <= plain.threshold_for(a) + 1e-12
        assert safe.reduction_for(a) <= plain.reduction_for(a) + 1e-12


# -------------------------------------------------------------- CORDS
def test_correlation_score_orders_dependence():
    rng = np.random.RandomState(0)
    a = rng.randint(0, 4, 20000)
    b_ind = rng.randint(0, 4, 20000)
    noise = rng.rand(20000) < 0.2
    b_dep = np.where(noise, rng.randint(0, 4, 20000), a)
    k_ind = correlation_score(a, b_ind)
    k_dep = correlation_score(a, b_dep)
    assert k_dep > 5 * k_ind
    assert 0 <= k_ind < 0.05
    assert k_dep > 0.3


def test_correlation_score_perfect_dependence():
    a = np.tile(np.arange(4), 2500)
    assert correlation_score(a, a) > 0.95
