"""Multi-query session serving (DESIGN.md §10): bit-identical per-query
emissions vs isolated servers (including across a mid-stream hot-swap of
one tenant only), cross-query UDF dedupe, conservation through the
shared scheduler, the WFQ starvation bound, and per-query epoch spaces
in the quorum-swap coordinator."""
import numpy as np
import pytest

from repro.core import CoreSession, OptimizeOptions, build_plan
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.distributed.consensus import (
    DriftEvent,
    DriftVote,
    MultiQueryCoordinator,
    ReservoirSample,
    SwapAck,
)
from repro.serving.engine import CascadeServer
from repro.serving.multiquery import (
    FairScheduler,
    MultiQueryEngine,
    eq31_benefit,
    udf_fingerprint,
)


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=7000, correlation=0.9, seed=31)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=31,
                     declared_cost_ms=10.0)
    return ds, udfs


@pytest.fixture(scope="module")
def session_run(workload):
    """One shared session (Q1 on cols [0,1], Q2 on [1,2] — they share
    column 1's UDF) driven in lockstep with two isolated twins, with a
    hot-swap of ONLY Q1's plan at a mid-stream chunk boundary."""
    ds, udfs = workload
    x_sample = ds.x[:1000]
    x_serve = ds.x[1000:4600]
    q1 = make_query(ds, udfs, columns=[0, 1], seed=33)
    q2 = make_query(ds, udfs, columns=[1, 2], seed=34)
    opts = OptimizeOptions(step=0.05, seed=31)

    session = CoreSession(options=opts)
    h1 = session.register_query(q1, x_sample)
    h2 = session.register_query(q2, x_sample)
    eng = session.serve()
    assert isinstance(eng, MultiQueryEngine)

    # a genuinely different Q1 plan (coarser allocation grid) so the swap
    # changes thresholds, not just the version number
    alt = build_plan(q1, x_sample, OptimizeOptions(mode="core-a", step=0.1,
                                                   seed=31))
    iso = [CascadeServer(h.plan, tile=1024, use_kernel=True, seed=31)
           for h in (h1, h2)]

    chunk, swap_at = 512, 2048
    q2_version_at_swap = None
    q2_swaps_at_swap = None
    for s0 in range(0, len(x_serve), chunk):
        if s0 == swap_at:
            q2_version_at_swap = eng.servers[1].plan_version
            q2_swaps_at_swap = eng.servers[1].stats.plan_swaps
            eng.install_plan(0, alt)
            iso[0].install_plan(alt)
        idx = np.arange(s0, min(s0 + chunk, len(x_serve)))
        eng.submit(idx, x_serve[idx])
        eng.pump()
        for srv in iso:
            srv.submit(idx, x_serve[idx])
            srv.pump()
    eng.drain()
    for srv in iso:
        while srv.in_flight():
            srv.pump(drain=True)
    return {"eng": eng, "iso": iso, "handles": (h1, h2),
            "q2_version_at_swap": q2_version_at_swap,
            "q2_swaps_at_swap": q2_swaps_at_swap,
            "n_serve": len(x_serve)}


# ------------------------------------------------- shared-mask property test
def test_emissions_bit_identical_to_isolated(session_run):
    """Stacked scoring rides the block-diagonal packed readout: a
    column's score has exact-zero cross-query terms, so every tenant's
    emitted-id multiset matches its isolated twin bit-for-bit — across
    the mid-stream swap of Q1's plan too (in-flight entries finish under
    the version that scored them, in both drivers)."""
    eng, iso = session_run["eng"], session_run["iso"]
    for qid in (0, 1):
        assert sorted(eng.servers[qid].emitted) == sorted(iso[qid].emitted)


def test_hot_swap_touches_only_target_tenant(session_run):
    eng = session_run["eng"]
    # Q1 swapped exactly once; Q2's plan version never moved
    assert eng.servers[0].stats.plan_swaps == 1
    assert eng.servers[1].stats.plan_swaps == session_run["q2_swaps_at_swap"]
    assert eng.servers[1].plan_version == session_run["q2_version_at_swap"]
    # the shared scorer restacked for the swap
    assert eng.stats.restacks >= 1


def test_conservation_and_dedupe(session_run):
    eng, n = session_run["eng"], session_run["n_serve"]
    ok, msg = eng.conserved()
    assert ok, msg
    st = eng.session_stats()
    # every submitted record was finalized exactly once per tenant
    assert st["finalized_per_query"] == [n, n]
    for qid in (0, 1):
        qs = eng.query_stats(qid)
        assert qs["in_flight"] == 0
        assert qs["emitted"] == len(eng.servers[qid].emitted)
    # Q1 and Q2 share column 1's UDF: identical (udf, record) evaluations
    # on the cascade tails are served from the session's result cache
    ded = st["dedupe"]
    assert ded["hits"] > 0
    assert ded["saved_cost_ms"] > 0.0
    assert 0.0 < ded["hit_rate"] < 1.0


def test_shared_udf_fingerprint_is_content_keyed(workload):
    ds, udfs = workload
    q1 = make_query(ds, udfs, columns=[0, 1], seed=33)
    q2 = make_query(ds, udfs, columns=[1, 2], seed=34)
    # both queries name column 1's UDF -> same fingerprint (dedupe key);
    # different columns' UDFs -> different fingerprints.  Predicates sit
    # in the order of the columns= list.
    fp1 = [udf_fingerprint(p.udf) for p in q1.predicates]
    fp2 = [udf_fingerprint(p.udf) for p in q2.predicates]
    assert fp1[1] == fp2[0]          # column 1 shared
    assert fp1[0] != fp2[1]          # column 0 vs column 2


# --------------------------------------------------------- WFQ starvation bound
def test_wfq_service_tracks_weights():
    """Both tenants continuously backlogged: per-prefix virtual times
    stay within one service quantum of each other (the classic WFQ
    bound), and cumulative service converges to the weight ratio."""
    w = {0: 1.0, 1: 4.0}
    sched = FairScheduler(w)
    quantum = 10.0
    for _ in range(200):
        q = sched.pick([0, 1])
        sched.charge(q, quantum)
    v = {0: 0.0, 1: 0.0}
    bound = quantum / min(w.values())
    for qid, cost in sched.service_log:
        v[qid] += cost / w[qid]
        assert abs(v[0] - v[1]) <= bound + 1e-9
    assert sched.served_cost[1] / sched.served_cost[0] == \
        pytest.approx(4.0, rel=0.15)


def test_wfq_no_banked_credit_on_reentry():
    """A tenant that sat idle while another was served re-enters at the
    incumbents' v-time floor: it may NOT burn its stale low clock as
    banked credit and monopolize the device (the starvation bound)."""
    sched = FairScheduler({0: 1.0, 1: 1.0})
    for _ in range(50):
        assert sched.pick([0]) == 0
        sched.charge(0, 10.0)
    grants = []
    for _ in range(10):
        q = sched.pick([0, 1])
        sched.charge(q, 10.0)
        grants.append(q)
    # equal weights -> near-alternation from the re-entry point on; the
    # newcomer must not take a run of grants proportional to idle time
    assert grants.count(1) <= 6
    assert 0 in grants[:2]


def test_wfq_pick_prefers_min_vtime_then_weight():
    sched = FairScheduler({0: 1.0, 1: 2.0, 2: 2.0})
    # fresh backlog, all clocks 0: tie broken to the heavier weight,
    # then the lower qid
    assert sched.pick([0, 1, 2]) == 1
    sched.charge(1, 4.0)   # vtime[1] = 2.0
    assert sched.pick([0, 1, 2]) == 2
    sched.charge(2, 4.0)   # vtime[2] = 2.0
    assert sched.pick([0, 1, 2]) == 0


def test_eq31_benefit_clipped_and_monotone(session_run):
    h1, h2 = session_run["handles"]
    for h in (h1, h2):
        b = eq31_benefit(h.plan)
        assert 0.1 <= b <= 100.0
        # a cascade that saves more cost gets more weight
        orig = sum(p.udf.cost for p in h.plan.query.predicates)
        assert b == pytest.approx(
            np.clip((orig - h.plan.est_total_cost)
                    / h.plan.est_total_cost, 0.1, 100.0))


# ----------------------------------------------- per-query epoch spaces (§10)
@pytest.fixture(scope="module")
def two_plans(workload):
    ds, udfs = workload
    x = ds.x[:1000]
    opts = OptimizeOptions(mode="core-a", step=0.05, kind="mixed", seed=31)
    qa = make_query(ds, udfs, columns=[0, 1], seed=51)
    qb = make_query(ds, udfs, columns=[1, 2], seed=52)
    return build_plan(qa, x, opts), build_plan(qb, x, opts)


def _vote(host, *, qid=0, epoch=0, escalated=False, n_rows=4):
    rng = np.random.default_rng(7 + host)
    return DriftVote(
        host=host, epoch=epoch,
        event=DriftEvent(at_record=100, signal="stage0:keep",
                         observed=0.1, expected=0.5, escalated=escalated),
        reservoir=ReservoirSample(
            indices=np.arange(n_rows) + 1000 * host,
            x=rng.standard_normal((n_rows, 3)).astype(np.float32),
            known_sigma={0: (np.ones(n_rows, bool),
                             rng.random(n_rows) < 0.5)},
            weights=np.ones(n_rows)),
        qid=qid)


def test_multiquery_coordinator_isolates_tenants(two_plans):
    """A pending prepare on one tenant's qid must not stall another
    tenant's full vote -> propose -> ack -> commit cycle: epochs live in
    per-query spaces and every outbound message is stamped with its
    qid."""
    pa, pb = two_plans
    mc = MultiQueryCoordinator({0: pa, 1: pb}, n_hosts=3,
                               reopt_fn=lambda plan, merged, mode: plan)
    assert mc.qids == [0, 1]

    # qid 0 reaches quorum and proposes -> its prepare is pending
    # (offer_vote returns True on the vote that COMPLETES the quorum)
    assert [mc.offer_vote(_vote(h, qid=0)) for h in range(2)] == [False, True]
    prep0 = mc.propose(0)
    assert prep0.qid == 0 and prep0.epoch == 1
    assert 0 in mc.pending_qids()
    # further qid-0 votes are dropped while ITS prepare is pending...
    assert not mc.offer_vote(_vote(2, qid=0))

    # ...but qid 1 runs a complete swap meanwhile
    assert [mc.offer_vote(_vote(h, qid=1)) for h in range(2)] == [False, True]
    prep1 = mc.propose(1)
    assert prep1.qid == 1 and prep1.epoch == 1
    commit1 = None
    for h in range(3):
        c = mc.offer_ack(SwapAck(host=h, epoch=prep1.epoch, ok=True,
                                 attempt=mc.coord(1).pending.attempt,
                                 qid=1))
        commit1 = c or commit1
    assert commit1 is not None and commit1.qid == 1
    assert mc.epoch(1) == 1
    assert mc.epoch(0) == 0          # qid 0 untouched by qid 1's commit
    assert 0 in mc.pending_qids() and 1 not in mc.pending_qids()

    # qid 0's own swap completes afterwards in its own epoch space
    commit0 = None
    for h in range(3):
        c = mc.offer_ack(SwapAck(host=h, epoch=prep0.epoch, ok=True,
                                 attempt=mc.coord(0).pending.attempt,
                                 qid=0))
        commit0 = c or commit0
    assert commit0 is not None and commit0.qid == 0
    assert mc.epoch(0) == 1 and mc.epoch(1) == 1


def test_multiquery_coordinator_routes_by_qid(two_plans):
    pa, pb = two_plans
    mc = MultiQueryCoordinator({0: pa, 1: pb}, n_hosts=3,
                               reopt_fn=lambda plan, merged, mode: plan)
    # a qid-1 vote lands on qid 1's coordinator only (not yet a quorum)
    assert not mc.offer_vote(_vote(0, qid=1))
    assert mc.coord(1).votes_pending == 1
    assert mc.coord(0).votes_pending == 0
    # fencing is a host property: it fans out to every tenant
    mc.mark_fenced(2)
    assert 2 in mc.coord(0).fenced and 2 in mc.coord(1).fenced
    mc.mark_rejoined(2)
    assert 2 not in mc.coord(0).fenced and 2 not in mc.coord(1).fenced
    # duplicate registration is rejected
    with pytest.raises(ValueError):
        mc.add_query(1, pb)
