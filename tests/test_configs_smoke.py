"""Per-architecture smoke tests (assignment requirement f).

For every assigned architecture: instantiate a REDUCED config of the same
family and run one forward + one train step + one prefill/decode step on CPU,
asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, SHAPES, supports_shape
from repro.models.registry import get_family, input_specs, make_batch
from repro.training.train_loop import init_train_state, make_train_step

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(42)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = reduced_config(arch)
    fam = get_family(cfg)
    params, opt_state = init_train_state(cfg, rng)
    seq = 32
    batch = make_batch(cfg, 2, seq, rng)
    logits = jax.jit(lambda p, b: fam.forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, batch["tokens"].shape[1], cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = jax.jit(make_train_step(cfg, lr=1e-3))
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    # params actually changed
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2),
    )
    assert changed, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch, rng):
    cfg = reduced_config(arch)
    fam = get_family(cfg)
    params = fam.init(rng, cfg)
    seq = 32
    batch = make_batch(cfg, 2, seq, rng)
    logits, cache = jax.jit(lambda p, b: fam.prefill(p, cfg, b))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite prefill logits"
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))(params, cache, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: non-finite decode logits"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not supports_shape(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert "cache" in specs
            leaves = jax.tree.leaves(specs["cache"])
            assert all(hasattr(l, "shape") for l in leaves)


def test_full_param_counts_match_published():
    expected = {
        "llama3-405b": 405e9,
        "qwen1.5-110b": 111e9,
        "deepseek-67b": 67e9,
        "deepseek-coder-33b": 33e9,
        "deepseek-v2-lite-16b": 15.7e9,
        "qwen3-moe-30b-a3b": 30.5e9,
        "recurrentgemma-2b": 2.7e9,
        "mamba2-2.7b": 2.7e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).n_params()
        assert abs(got - n) / n < 0.05, f"{arch}: {got/1e9:.1f}B vs published {n/1e9:.1f}B"


def test_reduced_param_count_matches_analytic():
    """Analytic n_params() agrees with the actual init for reduced configs."""
    for arch in ("llama3-405b", "qwen3-moe-30b-a3b", "mamba2-2.7b"):
        cfg = reduced_config(arch)
        fam = get_family(cfg)
        params = jax.eval_shape(lambda k: fam.init(k, cfg), jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.15, (arch, actual, analytic)
