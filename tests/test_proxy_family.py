"""ProxyFamily registry + packed-parameter format invariants.

* registry lookup by name, alias, and params type;
* pack/unpack round-trip across families (property test): stacking a
  mixed cascade into the bucket-padded (F, H, P) tensors and slicing one
  stage back out reproduces the per-proxy packed form bit-for-bit;
* the linear +/- embedding is EXACT through the kernel (packed two-pass
  scores bit-identical to the affine reference);
* packed reference scoring agrees with each family's native scorer;
* the builder's classifier cache is keyed on family: a mixed builder
  trains per-predicate families and never reuses across kinds.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.proxy_family import (
    HIDDEN_BUCKETS,
    cascade_kernel_operands,
    family_of,
    get_family,
    hidden_bucket,
    pack_cascade,
    unpack_cascade,
)
from repro.kernels import ref
from repro.kernels.proxy_score import proxy_score
from repro.training.proxy_models import (
    LinearParams,
    MLPParams,
    packed_score,
)


def _linear(rng, F):
    return LinearParams(
        w=rng.randn(F).astype(np.float32),
        b=np.float32(rng.randn()),
        mean=rng.randn(F).astype(np.float32),
        scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32),
    )


def _mlp(rng, F, H):
    return MLPParams(
        w1=rng.randn(F, H).astype(np.float32),
        b1=rng.randn(H).astype(np.float32),
        w2=rng.randn(H).astype(np.float32),
        b2=np.float32(rng.randn()),
        mean=rng.randn(F).astype(np.float32),
        scale=(np.abs(rng.randn(F)) + 0.5).astype(np.float32),
    )


# ---------------------------------------------------------------- registry
def test_registry_lookup_and_aliases():
    assert get_family("linear") is get_family("svm")
    assert get_family("mlp1") is get_family("mlp")
    with pytest.raises(KeyError):
        get_family("tree")
    rng = np.random.RandomState(0)
    assert family_of(_linear(rng, 4)).name == "linear"
    assert family_of(_mlp(rng, 4, 3)).name == "mlp1"


def test_hidden_bucket_ladder():
    assert [hidden_bucket(h) for h in (1, 2, 3, 4, 5, 32, 33, 128)] == \
        [2, 2, 4, 4, 8, 32, 64, 128]
    assert hidden_bucket(129) == 256  # beyond the ladder: top-bucket multiples
    assert all(b2 == 2 * b1 for b1, b2 in zip(HIDDEN_BUCKETS, HIDDEN_BUCKETS[1:]))


# ----------------------------------------------------- pack/unpack roundtrip
@given(
    f=st.integers(3, 48),
    n_stages=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip_mixed_families(f, n_stages, seed):
    """unpack_cascade(pack_cascade(params), col) == family.pack(params[col])
    bit-for-bit, for any mix of families and hidden widths (bucket padding
    must be inert and reversible)."""
    rng = np.random.RandomState(seed)
    params = []
    for _ in range(n_stages):
        if rng.rand() < 0.5:
            params.append(_linear(rng, f))
        else:
            params.append(_mlp(rng, f, rng.randint(1, 40)))
    packed = pack_cascade(params)
    assert packed.H == hidden_bucket(max(packed.hidden))
    for col, p in enumerate(params):
        fam = family_of(p)
        direct = fam.pack(p)
        rt = unpack_cascade(packed, col)
        assert rt.hidden == direct.hidden
        np.testing.assert_array_equal(rt.w1, direct.w1)
        np.testing.assert_array_equal(rt.b1, direct.b1)
        np.testing.assert_array_equal(rt.w2, direct.w2)
        assert rt.b2 == direct.b2
        # the bucket-pad slots must be exactly zero (inert under relu)
        assert not packed.w1[:, direct.hidden:, col].any()
        assert not packed.w2[direct.hidden:, col].any()


@given(
    f=st.integers(3, 32),
    n=st.integers(1, 200),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_packed_score_matches_family_score(f, n, seed):
    """The folded packed form scores within float tolerance of each
    family's native (standardize-then-score) path."""
    rng = np.random.RandomState(seed)
    x = rng.randn(n, f).astype(np.float32)
    for params in (_linear(rng, f), _mlp(rng, f, rng.randint(1, 20))):
        fam = family_of(params)
        native = np.asarray(fam.score(params, x))
        folded = packed_score(fam.pack(params), x)
        np.testing.assert_allclose(folded, native, rtol=1e-4, atol=1e-4)


def test_linear_embedding_exact_through_kernel():
    """The +/- trick is exact: the two-pass packed kernel's scores are
    bit-identical to the single affine reference for a linear stack."""
    rng = np.random.RandomState(3)
    F, N, P = 24, 300, 4
    x = rng.randn(N, F).astype(np.float32)
    w = rng.randn(F, P).astype(np.float32)
    b = rng.randn(P).astype(np.float32)
    thr = rng.randn(P).astype(np.float32)
    s, m = proxy_score(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                       jnp.asarray(thr), interpret=True)
    sref, mref = ref.proxy_score_ref(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), jnp.asarray(thr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sref))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mref))


def test_cascade_kernel_operands_layout():
    """h-major flattening: column h*P + p of w1 is hidden unit h of stage
    p, and w2 is the matching block-diagonal readout."""
    rng = np.random.RandomState(5)
    params = [_linear(rng, 6), _mlp(rng, 6, 3)]
    packed = pack_cascade(params)
    w1, b1, w2, b2 = cascade_kernel_operands(packed)
    H, P = packed.H, packed.n_stages
    assert w1.shape == (6, H * P) and w2.shape == (H * P, P)
    for h in range(H):
        for p in range(P):
            np.testing.assert_array_equal(w1[:, h * P + p], packed.w1[:, h, p])
            assert b1[h * P + p] == packed.b1[h, p]
            # readout row touches exactly its own stage's column
            expect = np.zeros(P, np.float32)
            expect[p] = packed.w2[h, p]
            np.testing.assert_array_equal(w2[h * P + p], expect)


# ----------------------------------------------------- builder family keying
def test_builder_mixed_assigns_families_and_keys_cache():
    from repro.core.builder import ProxyBuilder
    from repro.data.synthetic import make_dataset, make_query, make_udfs

    ds = make_dataset(n=3000, correlation=0.85, seed=11)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=800, seed=11,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5, seed=12)
    b = ProxyBuilder(q, ds.x[:800], kind="mixed")
    assert b.family_for(0) == "linear" and b.family_for(1) == "mlp1"
    p0, _ = b.get_proxy(0, ())
    p1, _ = b.get_proxy(1, ())
    assert p0.family == "linear" and p1.family == "mlp1"
    # cache keys carry the family; same (pred, prefix) under another family
    # is a MISS, not a cross-family reuse
    assert (0, frozenset(), "linear") in b._proxies
    b2 = ProxyBuilder(q, ds.x[:800], kind="mlp")
    b2._proxies = dict(b._proxies)  # transplant, as rebase does
    q0, _ = b2.get_proxy(0, ())
    assert q0.family == "mlp1"
    assert b2.stats.n_reused == 0
    # per-predicate family map (how reoptimize pins an incumbent plan's
    # exact assignment, parity rule or not)
    b3 = ProxyBuilder(q, ds.x[:800], kind={0: "mlp1", 1: "linear"})
    assert b3.family_for(0) == "mlp1" and b3.family_for(1) == "linear"
