"""Fault-tolerant multi-host serving (DESIGN.md §6 failure model):
COREWIRE v1.1 control frames, standby-coordinator replication + takeover,
straggler fencing with serve-behind + re-sync, cross-host kappa² pooling,
the process-level transport, and the consensus edge cases (duplicate
votes, acks after abort, K=2 quorum arithmetic)."""
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # benchmarks/

from repro.core import optimize
from repro.core.correlation import StreamingKappa2
from repro.data.synthetic import (
    make_dataset,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.distributed.consensus import (
    DriftVote,
    QuorumSwapCoordinator,
    StandbyCoordinator,
    StateDelta,
    SwapAck,
    SwapCommit,
    kappa_export_from_json,
    kappa_export_to_json,
    quorum,
)
from repro.distributed.serving import ShardedCascadeServer
from repro.kernels.ops import (
    FRAME_DELTA,
    FRAME_RESYNC,
    WireFormatError,
    deserialize_frame,
    deserialize_scorer,
    serialize_frame,
    serialize_scorer,
)
from repro.serving.stats import AdaptivePolicy, DriftEvent, ReservoirSample


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=9000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=41)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=41,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=42)
    return ds, q


def _policy(**kw):
    base = dict(cooldown_records=1024, min_reservoir=128, threshold=50.0,
                audit_rate=0.03, reservoir_capacity=512)
    base.update(kw)
    return AdaptivePolicy(**base)


def _plan(workload):
    ds, q = workload
    return optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)


def _streams(workload, n_hosts=4, n_before=800, n_after=2400):
    ds, _q = workload
    return make_sharded_drifting_streams(
        ds, n_hosts, n_before, n_after,
        shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)


def _assert_conserved(srv, stats):
    assert stats.submitted == stats.emitted + stats.rejected
    all_emitted = []
    for h in srv.hosts:
        e = h.engine
        assert e.in_flight() == 0
        assert len(e.emitted) == len(set(e.emitted))
        assert len(e.emitted) == len(e.emitted_versions)
        for i, v in zip(e.emitted, e.emitted_versions):
            assert h.submit_version[i] == v
        all_emitted.extend(e.emitted)
    assert len(all_emitted) == len(set(all_emitted))


# --------------------------------------------------- COREWIRE v1.1 frames
def test_frame_roundtrip_and_discrimination(workload):
    ds, q = workload
    plan = _plan(workload)
    artifact = serialize_scorer(plan)
    frame = serialize_frame(FRAME_RESYNC, 7, artifact, meta={"host": 3})
    kind, epoch, payload, meta = deserialize_frame(frame)
    assert (kind, epoch, meta) == (FRAME_RESYNC, 7, {"host": 3})
    assert payload == artifact  # artifact bytes ride through untouched
    plan2, scorer2 = deserialize_scorer(payload, q)
    assert plan2.order == plan.order
    # the two channels cannot be confused in either direction
    with pytest.raises(WireFormatError):
        deserialize_scorer(frame, q)  # frame is not an artifact
    with pytest.raises(WireFormatError):
        deserialize_frame(artifact)  # artifact is not a frame
    # v1 artifact bytes are untouched by the v1.1 addition
    assert artifact[:8] == b"COREWIRE" and artifact[10:12] == b"\x00\x00"
    # truncated frame payloads are detected
    with pytest.raises(WireFormatError):
        deserialize_frame(frame[:-10])


def test_delta_frame_carries_consensus_state():
    delta_payload = b"\x00\x01binary-artifact-bytes\xff"
    frame = serialize_frame(FRAME_DELTA, 3, delta_payload,
                            meta={"kind": "prepare", "host": None})
    kind, epoch, payload, meta = deserialize_frame(frame)
    assert kind == FRAME_DELTA and epoch == 3
    assert payload == delta_payload
    assert meta["kind"] == "prepare" and meta["host"] is None


# --------------------------------------------------- kappa pooling pieces
@given(n_rows=st.integers(8, 80), n_hosts=st.integers(1, 5),
       seed=st.integers(0, 5000))
@settings(max_examples=25, deadline=None)
def test_kappa_merge_matches_single_tracker(n_rows, n_hosts, seed):
    """Summing K shards' exported contingency tables yields exactly the
    kappa² of one tracker fed the union of their rows — the property the
    coordinator's fleet pooling rests on."""
    rng = np.random.RandomState(seed)
    a = rng.randint(0, 3, n_rows)
    b = rng.randint(0, 3, n_rows)
    w = 1.0 / rng.uniform(0.05, 1.0, n_rows)
    assign = rng.randint(0, n_hosts, n_rows)
    single = StreamingKappa2()
    single.update(a, b, weights=w)
    parts = [StreamingKappa2() for _ in range(n_hosts)]
    for k in range(n_hosts):
        m = assign == k
        if m.any():
            parts[k].update(a[m], b[m], weights=w[m])
    pooled = StreamingKappa2()
    for p in parts:
        pooled.merge_counts(*p.export())
    assert pooled.n_rows == single.n_rows == n_rows
    assert abs(pooled.value() - single.value()) < 1e-12


def test_kappa_export_json_roundtrip():
    k = StreamingKappa2()
    k.update([0, 1, 1, 2], [1, 1, 0, 2], weights=[1.0, 2.5, 3.0, 1.5])
    export = {(0, 1): k.export(), (0, 2): k.export()}
    back = kappa_export_from_json(kappa_export_to_json(export))
    assert back.keys() == export.keys()
    for pair in export:
        c1, n1, r1 = export[pair]
        c2, n2, r2 = back[pair]
        assert c1 == c2 and n1 == n2 and r1 == r2
    assert kappa_export_from_json(kappa_export_to_json(None)) is None


# ------------------------------------------------- consensus edge cases
def _vote(host, epoch=0, escalated=False, n_rows=4):
    rng = np.random.RandomState(host)
    return DriftVote(
        host=host, epoch=epoch,
        event=DriftEvent(at_record=100, signal="stage0:keep",
                         observed=0.1, expected=0.5, escalated=escalated),
        reservoir=ReservoirSample(
            indices=np.arange(n_rows) + 1000 * host,
            x=rng.randn(n_rows, 3).astype(np.float32),
            known_sigma={0: (np.ones(n_rows, bool),
                             rng.random_sample(n_rows) < 0.5)},
            weights=np.ones(n_rows),
        ),
    )


@pytest.fixture(scope="module")
def mixed_plan(workload):
    ds, q = workload
    return optimize(q, ds.x[:1200], mode="core-a", step=0.05, kind="mixed")


def test_duplicate_votes_do_not_double_merge(mixed_plan):
    """A host re-sending its vote within one epoch is dropped BEFORE the
    merge: the merged optimization sample must count each host's
    reservoir exactly once or pooled estimates double-weight that
    shard."""
    merged_rows = []
    coord = QuorumSwapCoordinator(
        mixed_plan, 3,
        reopt_fn=lambda p, m, mode: merged_rows.append(m.n_rows) or mixed_plan)
    assert not coord.offer_vote(_vote(0))
    for _ in range(5):  # persistent duplicate sender
        assert not coord.offer_vote(_vote(0))
    assert coord.votes_pending == 1
    assert coord.offer_vote(_vote(1))  # quorum(3) == 2
    coord.propose()
    assert merged_rows == [8]  # 2 hosts x 4 rows; duplicates contributed 0


def test_prepare_ack_after_abort_is_inert(mixed_plan):
    """Late acks for an aborted epoch (straggler finally answering after
    the round died) must not resurrect the swap or leak into any later
    round's barrier accounting."""
    coord = QuorumSwapCoordinator(
        mixed_plan, 3, reopt_fn=lambda p, m, mode: mixed_plan)
    for h in range(2):
        coord.offer_vote(_vote(h))
    coord.propose()
    att1 = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=att1)) is None
    assert coord.offer_ack(
        SwapAck(host=1, epoch=1, ok=False, error="boom",
                attempt=att1)) is None  # abort
    assert coord.pending is None
    # the straggling host 2 answers AFTER the abort: inert
    assert coord.offer_ack(SwapAck(host=2, epoch=1, ok=True,
                                   attempt=att1)) is None
    assert coord.pending is None and coord.epoch == 0
    assert [r.committed for r in coord.swap_log] == [False]
    # a NEW round must need a fresh full barrier (the late ack from the
    # dead round may not count toward this one) — note the retried round
    # re-proposes the SAME epoch number: aborts do not advance it
    for h in range(2):
        coord.offer_vote(_vote(h))
    prep2 = coord.propose()
    assert prep2.epoch == 1
    # same epoch NUMBER, fresh attempt nonce: round-1 acks cannot leak in
    assert prep2.attempt == att1 + 1
    a2 = prep2.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=a2)) is None
    assert coord.offer_ack(SwapAck(host=1, epoch=1, ok=True,
                                   attempt=a2)) is None
    assert coord.offer_ack(SwapAck(host=2, epoch=1, ok=True,
                                   attempt=a2)) is not None
    assert coord.epoch == 1


def test_fenced_host_ack_after_fence_is_inert(mixed_plan):
    """A straggler is fenced out of the barrier while its prepare-ack is
    still in flight (protocol_check.py: deadline_fence then deliver_ack).
    The late ack must be inert: it may not close the shrunken barrier or
    re-enter the fenced host into barrier accounting — the commit must
    come from live acks only."""
    coord = QuorumSwapCoordinator(
        mixed_plan, 3, reopt_fn=lambda p, m, mode: mixed_plan)
    for h in range(2):
        coord.offer_vote(_vote(h))
    coord.propose()
    att = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=att)) is None
    coord.mark_fenced(2)  # deadline resolution: barrier shrinks to {0, 1}
    # the fenced host's ack lands AFTER its fence: inert
    assert coord.offer_ack(SwapAck(host=2, epoch=1, ok=True,
                                   attempt=att)) is None
    assert coord.pending is not None  # barrier still open
    commit = coord.offer_ack(SwapAck(host=1, epoch=1, ok=True, attempt=att))
    assert commit is not None and commit.epoch == 1
    assert coord.epoch == 1


def test_stale_attempt_ack_during_retry_round_is_inert(mixed_plan):
    """The interleaving protocol_check.py's legacy mode flags: round 1 on
    epoch 1 aborts, the retry round re-proposes the SAME epoch number,
    and a round-1 ack then arrives MID-round-2.  The epoch matches, so
    only the attempt nonce distinguishes the rounds — without it the
    stale ack closes the barrier and a host installs an artifact no
    coordinator committed."""
    coord = QuorumSwapCoordinator(
        mixed_plan, 3, reopt_fn=lambda p, m, mode: mixed_plan)
    for h in range(2):
        coord.offer_vote(_vote(h))
    coord.propose()
    att1 = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=att1)) is None
    assert coord.offer_ack(
        SwapAck(host=1, epoch=1, ok=False, error="slow",
                attempt=att1)) is None  # abort round 1
    for h in range(2):
        coord.offer_vote(_vote(h))
    prep2 = coord.propose()
    att2 = prep2.attempt
    assert prep2.epoch == 1 and att2 == att1 + 1
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=att2)) is None
    # host 2's ROUND-1 ack finally arrives: same epoch, stale attempt
    assert coord.offer_ack(SwapAck(host=2, epoch=1, ok=True,
                                   attempt=att1)) is None
    assert coord.pending is not None  # must NOT have closed the barrier
    assert coord.offer_ack(SwapAck(host=1, epoch=1, ok=True,
                                   attempt=att2)) is None
    commit = coord.offer_ack(SwapAck(host=2, epoch=1, ok=True, attempt=att2))
    assert commit is not None and commit.attempt == att2
    assert coord.epoch == 1


def test_quorum_k2_is_unanimity(mixed_plan):
    """K=2: strict majority is floor(2/2)+1 = 2, i.e. BOTH hosts must
    vote and both must ack — one noisy host can never swap alone, and
    one dead host blocks the swap (which fencing then resolves)."""
    assert quorum(2) == 2
    coord = QuorumSwapCoordinator(
        mixed_plan, 2, reopt_fn=lambda p, m, mode: mixed_plan)
    assert coord.quorum_size == 2
    assert not coord.offer_vote(_vote(0))  # one vote is NOT quorum at K=2
    with pytest.raises(RuntimeError):
        coord.propose()
    assert coord.offer_vote(_vote(1))
    coord.propose()
    a = coord.pending.attempt
    assert coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                                   attempt=a)) is None
    commit = coord.offer_ack(SwapAck(host=1, epoch=1, ok=True, attempt=a))
    assert commit is not None and coord.epoch == 1
    # ...and with one host fenced, K=2 degrades to a quorum of one
    coord.mark_fenced(1)
    assert coord.quorum_size == 1


# --------------------------------------------- standby coordinator (unit)
class _StubHost:
    def __init__(self, host_id, epoch=0, staged=None):
        self.host_id = host_id
        self.epoch = epoch
        self._staged = staged  # epoch the host staged, or None
        self.committed = []
        self.aborted = 0

    def commit(self, msg):
        if self._staged != msg.epoch:
            raise RuntimeError("no staged plan")
        self.epoch = msg.epoch
        self._staged = None
        self.committed.append(msg.epoch)

    def abort(self):
        self._staged = None
        self.aborted += 1


def _standby(plan, n_hosts=3):
    return StandbyCoordinator(plan, n_hosts,
                              reopt_fn=lambda p, m, mode: plan)


def test_standby_mirrors_deltas(mixed_plan):
    sb = _standby(mixed_plan)
    sb.apply(StateDelta(kind="vote", epoch=0, host=0))
    sb.apply(StateDelta(kind="vote", epoch=0, host=2))
    assert sb.voted == {0, 2}
    sb.apply(StateDelta(kind="prepare", epoch=1, artifact=b"abc"))
    assert sb.pending == (1, b"abc")
    sb.apply(StateDelta(kind="ack", epoch=1, host=0))
    assert sb.acks == {0}
    sb.apply(StateDelta(kind="commit", epoch=1, artifact=b"abc"))
    assert sb.epoch == 1 and sb.pending is None and sb.voted == set()
    assert sb.last_artifact == b"abc"
    sb.apply(StateDelta(kind="fence", epoch=1, host=2))
    assert sb.fenced == {2}
    sb.apply(StateDelta(kind="rejoin", epoch=1, host=2))
    assert sb.fenced == set()


def test_standby_takeover_completes_closed_barrier(mixed_plan):
    """Primary died between collecting the last ack and broadcasting the
    commit (no commit delta): every active host staged + acked, so the
    standby COMPLETES the install."""
    sb = _standby(mixed_plan)
    sb.apply(StateDelta(kind="prepare", epoch=1, artifact=b"abc"))
    for h in range(3):
        sb.apply(StateDelta(kind="ack", epoch=1, host=h))
    hosts = [_StubHost(h, epoch=0, staged=1) for h in range(3)]
    coord, resolution = sb.take_over(hosts)
    assert resolution == "completed"
    assert coord.epoch == 1 and coord.last_artifact == b"abc"
    assert all(h.epoch == 1 for h in hosts)
    assert coord.swap_log[-1].committed \
        and coord.swap_log[-1].initiated_by == "failover"


def test_standby_takeover_aborts_open_barrier(mixed_plan):
    """Primary died mid-prepare (partial staging, partial acks): nothing
    installed anywhere, so the standby cleanly ABORTS — staged copies
    drop, voting re-arms, the epoch does not advance."""
    sb = _standby(mixed_plan)
    sb.apply(StateDelta(kind="vote", epoch=0, host=0))
    sb.apply(StateDelta(kind="prepare", epoch=1, artifact=b"abc"))
    sb.apply(StateDelta(kind="ack", epoch=1, host=0))
    hosts = [_StubHost(0, staged=1), _StubHost(1, staged=1), _StubHost(2)]
    coord, resolution = sb.take_over(hosts)
    assert resolution == "aborted"
    assert coord.epoch == 0
    assert all(h.aborted == 1 for h in hosts)
    assert all(h._staged is None for h in hosts)
    assert not coord.swap_log[-1].committed


def test_standby_takeover_resyncs_after_lost_commit_broadcast(mixed_plan):
    """Primary committed internally (commit delta replicated) but died
    mid-broadcast: one host installed, the rest are behind — takeover
    fences them for COREWIRE re-sync instead of re-running the barrier."""
    sb = _standby(mixed_plan)
    sb.apply(StateDelta(kind="prepare", epoch=1, artifact=b"abc"))
    for h in range(3):
        sb.apply(StateDelta(kind="ack", epoch=1, host=h))
    sb.apply(StateDelta(kind="commit", epoch=1, artifact=b"abc"))
    hosts = [_StubHost(0, epoch=1), _StubHost(1, epoch=0),
             _StubHost(2, epoch=0)]
    coord, resolution = sb.take_over(hosts)
    assert resolution == "resync"
    assert coord.epoch == 1
    assert coord.fenced == {1, 2}  # behind hosts await re-sync
    assert hosts[0].epoch == 1  # the installed host is untouched


def test_snapshot_deltas_rearm_open_barrier(mixed_plan):
    """A replacement standby registered AFTER a takeover starts blind —
    snapshot_deltas() re-emits the live coordinator state (votes, open
    prepare barrier, partial acks) so a replayed standby mirrors it
    exactly and a SECOND failover can resolve the same barrier."""
    coord = QuorumSwapCoordinator(
        mixed_plan, 3, reopt_fn=lambda p, m, mode: mixed_plan)
    coord.offer_vote(_vote(0))
    coord.offer_vote(_vote(1))
    coord.propose()
    coord.offer_ack(SwapAck(host=0, epoch=1, ok=True,
                            attempt=coord.pending.attempt))
    sb = _standby(mixed_plan)
    for delta in coord.snapshot_deltas():
        sb.apply(delta)
    assert sb.voted == {0, 1}
    assert sb.pending == (1, coord.pending.artifact)
    assert sb.acks == {0}
    assert sb.epoch == 0 and sb.last_artifact is None


def test_snapshot_deltas_rearm_committed_state(mixed_plan):
    """After a committed epoch with a fenced host, the snapshot replays
    the commit (with artifact, for future re-syncs) and the fence."""
    coord = QuorumSwapCoordinator(
        mixed_plan, 3, reopt_fn=lambda p, m, mode: mixed_plan)
    coord.mark_fenced(2)
    coord.offer_vote(_vote(0))
    coord.offer_vote(_vote(1))
    coord.propose()
    a = coord.pending.attempt
    coord.offer_ack(SwapAck(host=0, epoch=1, ok=True, attempt=a))
    commit = coord.offer_ack(SwapAck(host=1, epoch=1, ok=True, attempt=a))
    assert commit is not None and coord.epoch == 1
    sb = _standby(mixed_plan)
    for delta in coord.snapshot_deltas():
        sb.apply(delta)
    assert sb.epoch == 1
    assert sb.last_artifact == coord.last_artifact
    assert sb.fenced == {2}
    assert sb.pending is None


# ------------------------------------------------ end-to-end failover
def test_failover_completes_swap_mid_epoch(workload):
    """Acceptance: the primary dies after the barrier closed but before
    the commit broadcast; the standby takes over mid-epoch and the fleet
    still converges on the committed swap — conservation holds and no
    host ever serves an unacknowledged version."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               kill_coordinator_at="commit")
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    assert stats.failovers == 1
    assert stats.failover_resolution == "resync"
    assert stats.swaps_committed >= 1
    assert stats.resyncs == 4  # the whole fleet caught up via re-sync
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    assert stats.final_epoch >= 1
    _assert_conserved(srv, stats)


def test_failover_aborts_partial_prepare_then_recovers(workload):
    """The primary dies with the prepare half-broadcast (some hosts
    staged, no closed barrier): the standby must cleanly ABORT — and the
    recovered fleet must still be able to commit a later swap."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               kill_coordinator_at="prepare")
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    assert stats.failovers == 1
    assert stats.failover_resolution == "aborted"
    assert stats.swaps_aborted >= 1
    assert stats.swaps_committed >= 1  # voting re-armed; the fleet recovered
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    _assert_conserved(srv, stats)


def test_failover_mid_commit_broadcast(workload):
    """Hardest corner: the primary dies with ONE host installed.  An
    abort would strand that host, so the takeover must drive everyone
    else forward (re-sync), never backward."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               kill_coordinator_at="mid-commit")
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    assert stats.failovers == 1
    assert stats.failover_resolution == "resync"
    assert stats.resyncs == 3  # everyone but the already-installed host
    assert stats.swaps_committed >= 1
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    _assert_conserved(srv, stats)


def test_failover_rearmed_standby_survives_second_kill(workload):
    """Acceptance (re-arm): after the first takeover the promoted
    coordinator registers a FRESH standby and replays its live state via
    snapshot_deltas(), so killing the SECOND primary must also resolve
    cleanly — two failovers, two re-arms, fleet still converged and
    conserved.  Without re-arm the second kill would strand the fleet
    with no coordinator at all."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               kill_coordinator_at=(2000, "commit"))
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    assert stats.failovers == 2
    assert stats.standby_rearms == 2
    assert stats.failover_resolution in ("completed", "aborted", "resync")
    assert stats.swaps_committed >= 1
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    assert stats.final_epoch >= 1
    _assert_conserved(srv, stats)


# ------------------------------------------------- straggler fencing
def test_straggler_fenced_serves_behind_then_resyncs(workload):
    """Acceptance: a silent host neither blocks the commit (the fleet
    commits with K-1 acks) nor serves an unacked version (it stays
    pinned on its old epoch until the COREWIRE re-sync)."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               straggler_host=2)
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    straggler = srv.hosts[2]
    assert stats.fences == 1
    assert stats.resyncs >= 1 and straggler.resyncs >= 1
    assert stats.swaps_committed >= 1  # the straggler did not block commit
    fenced_swaps = [r for r in stats.swap_log if r.committed and r.fenced]
    assert fenced_swaps and fenced_swaps[0].fenced == [2]
    # serve-behind: everything the straggler served while the fleet was
    # at epoch>=1 ran under ITS pinned version, never an unacked one
    fence_epoch = fenced_swaps[0].epoch
    for i, v in zip(straggler.engine.emitted,
                    straggler.engine.emitted_versions):
        assert v == straggler.submit_version[i]
        assert v in (0, fence_epoch) or v > fence_epoch
    # after rejoin the whole fleet agrees again
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    _assert_conserved(srv, stats)


def test_straggler_nack_policy_aborts(workload):
    """policy="nack": a deadline miss is a NACK — the epoch aborts
    fleet-wide instead of fencing, and serving continues on the old
    plan."""
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256,
                               policy=_policy(), seed=3,
                               straggler_host=2, straggler_policy="nack")
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in _streams(workload)], chunk=400)
    assert stats.fences == 0
    assert stats.swaps_aborted >= 1
    aborted = [r for r in stats.swap_log if not r.committed]
    assert aborted and aborted[0].aborted_by == 2
    # the healed host re-enters quorum: a later swap can still commit
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    _assert_conserved(srv, stats)


# ------------------------------------------------ cross-host kappa² pool
def test_pooled_kappa_escalates_split_correlation_drift(workload):
    """Acceptance: a correlation-only drift split evenly across K=4
    shards fires NO local detector (zero votes, every escalation hint
    says alloc) — yet the pooled contingency tables cross the fleet
    baseline's tolerance and the coordinator escalates straight to a
    B&B re-search."""
    ds, q = workload
    streams = make_sharded_drifting_streams(
        ds, 4, 1200, 2600, shift_targets={}, shift=0.0, corr_gain=3.0,
        drift_skew=0.3, skew_corr=True, seed=41)
    srv = ShardedCascadeServer(
        _plan(workload), 4, tile=256, seed=3,
        policy=_policy(threshold=200.0, kappa_pool_baseline=60))
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.votes_cast == 0  # no shard's local detector fired
    assert stats.pooled_swaps >= 1
    pooled = [r for r in stats.swap_log if r.initiated_by == "pooled:kappa2"]
    assert pooled and all(r.mode == "bnb" for r in pooled)
    assert all(r.voters == [] for r in pooled)
    assert stats.swaps_committed >= 1
    # the locals stayed quiet even at end of stream
    for h in srv.hosts:
        mode, escalated = h.engine.escalation_hint()
        assert not escalated
    _assert_conserved(srv, stats)


def test_pooled_kappa_disabled_by_default(workload):
    """The same split correlation drift with the default policy
    (kappa_pool_baseline=0) swaps nothing: pooling is an explicit
    opt-in — the coordinator may not open unvoted swaps unless asked."""
    ds, q = workload
    streams = make_sharded_drifting_streams(
        ds, 4, 1200, 1800, shift_targets={}, shift=0.0, corr_gain=3.0,
        drift_skew=0.3, skew_corr=True, seed=41)
    srv = ShardedCascadeServer(_plan(workload), 4, tile=256, seed=3,
                               policy=_policy(threshold=200.0))
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.pooled_swaps == 0
    assert stats.swaps_committed == 0


# ------------------------------------------------- process transport
@pytest.mark.slow
@pytest.mark.flaky
def test_process_transport_fleet(workload):
    """One host per OS subprocess speaking COREWIRE + newline-JSON over
    pipes: the same quorum swap commits across real process boundaries
    and the conservation invariants survive the marshalling."""
    ds, q = workload
    spec = {
        "dataset": dict(n=7000, n_features=64, n_columns=3, correlation=0.9,
                        feature_noise=0.9, label_noise=0.2, seed=41),
        "udfs": dict(hidden=16, depth=1, train_rows=1000, seed=41,
                     declared_cost_ms=10.0),
        "query": dict(columns=[0, 1, 2], target_selectivity=0.5,
                      accuracy_target=0.9, seed=42),
    }
    ds2 = make_dataset(**spec["dataset"])
    udfs2 = make_udfs(ds2, **spec["udfs"])
    q2 = make_query(ds2, udfs2, **spec["query"])
    plan = optimize(q2, ds2.x[:1200], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds2, 2, 700, 2000, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    srv = ShardedCascadeServer(plan, 2, tile=256, policy=_policy(), seed=3,
                               transport="process", worker_spec=spec)
    for h in srv.hosts:
        h.track_versions = True
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert stats.swaps_committed >= 1
    assert {h.epoch for h in srv.hosts} == {stats.final_epoch}
    _assert_conserved(srv, stats)


@pytest.mark.slow
@pytest.mark.flaky
def test_process_transport_slo_frontend(workload):
    """slo_ms crosses the process boundary: each subprocess host runs an
    SLO front end worker-side, its FrontEndStats ride the drain reply
    back over the pipe, and fleet_goodput_ratio aggregates them — the
    thread transport's goodput contract, minus the shared memory."""
    spec = {
        "dataset": dict(n=7000, n_features=64, n_columns=3, correlation=0.9,
                        feature_noise=0.9, label_noise=0.2, seed=41),
        "udfs": dict(hidden=16, depth=1, train_rows=1000, seed=41,
                     declared_cost_ms=10.0),
        "query": dict(columns=[0, 1, 2], target_selectivity=0.5,
                      accuracy_target=0.9, seed=42),
    }
    ds2 = make_dataset(**spec["dataset"])
    udfs2 = make_udfs(ds2, **spec["udfs"])
    q2 = make_query(ds2, udfs2, **spec["query"])
    plan = optimize(q2, ds2.x[:1200], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds2, 2, 700, 2000, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=41)
    # generous per-chunk deadline: every request should meet its SLO
    slo = 200.0 * plan.est_total_cost * 400
    srv = ShardedCascadeServer(plan, 2, tile=256,
                               policy=_policy(threshold=200.0), seed=3,
                               transport="process", worker_spec=spec,
                               slo_ms=slo)
    stats = srv.run_streams([s.x for s in streams], chunk=400)
    assert len(stats.frontend_stats) == 2
    assert all(f.requests_done > 0 for f in stats.frontend_stats)
    assert all(f.requests_rejected_admission == 0
               for f in stats.frontend_stats)
    assert stats.fleet_goodput_ratio > 0.0
    # frontend-aware conservation at fleet level (the engines live in
    # the subprocesses; their row-level invariants are checked worker-side)
    shed = sum(f.records_shed for f in stats.frontend_stats)
    assert stats.submitted == stats.emitted + stats.rejected + shed
