"""Model-checker tests: the strict swap protocol is safe in the bounded
space, and the checker keeps its teeth — the pre-attempt-nonce ("legacy")
state machine must still be caught installing an artifact no coordinator
committed.
"""
import pytest

from repro.analysis.protocol_check import CheckConfig, check

#: state count of the full strict K=3 space at the time the checker was
#: wired into CI.  The space may legitimately GROW (new actions modeled);
#: shrinking below this floor means the enumeration silently lost reach.
STRICT_K3_STATE_FLOOR = 739_759


def test_strict_small_fleet_is_safe_and_live():
    res = check(CheckConfig(n_hosts=2))
    assert res.violation is None
    assert all(res.witnesses.values()), res.witnesses


@pytest.mark.slow
def test_strict_full_bounded_space():
    """The acceptance run: K=3 hosts, 2 in-flight epochs, 1 crash + 1
    straggler fence — every interleaving, all five invariants."""
    res = check(CheckConfig(n_hosts=3))
    assert res.violation is None
    assert all(res.witnesses.values()), res.witnesses
    assert res.states_explored >= STRICT_K3_STATE_FLOOR


def test_legacy_acks_reproduce_the_stale_ack_bug():
    """Without the attempt nonce, a stale round-1 prepare-ack closes a
    round-2 barrier and a host installs an artifact that was never
    committed.  The checker must find this — it is the regression test
    that the model has teeth."""
    res = check(CheckConfig(n_hosts=3, legacy_acks=True))
    assert res.violation is not None
    assert res.violation.invariant in ("I1-serve-only-acked", "I5-unique-commit")
    # the trace is a real interleaving, not an empty stub
    assert len(res.violation.trace) >= 5
    assert any("takeover" in step or "deliver_ack" in step
               for step in res.violation.trace)


def test_witnesses_cover_abort_and_failover_paths():
    res = check(CheckConfig(n_hosts=2))
    assert res.witnesses["I3-repropose-after-abort"]
    assert res.witnesses["I4-fence-survives-abort"]
    assert res.witnesses["failover-reachable"]
