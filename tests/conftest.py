"""Test bootstrap: fall back to the local hypothesis shim when the real
package is not installed (the container has no network / pip)."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_shim

    _hypothesis_shim.install()
